import os
import time

import numpy as np
import pytest

# Hypothesis settings profiles, selected via HYPOTHESIS_PROFILE (default
# "dev"). Both print the reproduction blob on failure so a property-test
# counterexample can be replayed locally; "ci" additionally relaxes the
# per-example deadline (shared runners stall unpredictably — a slow example
# is not a flaky failure) and prints statistics for triage. CI uploads the
# .hypothesis example database as an artifact on failure, so the shrunk
# counterexample survives the runner.
try:
    from hypothesis import settings
    settings.register_profile("dev", print_blob=True)
    settings.register_profile("ci", print_blob=True, deadline=None,
                              derandomize=False)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:  # hypothesis-free environments still run the rest
    pass


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: chaos/socket acceptance tests — excluded from the fast "
        "tier-1 job (-m 'not slow'), always run in the cluster matrix rows")


def wait_until(predicate, timeout: float = 10.0, interval: float = 0.02,
               desc: str = "condition"):
    """Bounded poll: the deflaked replacement for fixed ``time.sleep`` waits
    in timing-sensitive tests (reap/renew TTL races). Returns as soon as
    ``predicate()`` is truthy; a loaded runner just polls longer instead of
    failing, and a genuinely broken condition fails loudly at ``timeout``
    instead of passing by luck."""
    deadline = time.monotonic() + timeout
    while True:
        got = predicate()
        if got:
            return got
        if time.monotonic() >= deadline:
            raise AssertionError(f"timed out after {timeout}s waiting for "
                                 f"{desc}")
        time.sleep(interval)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
