"""Sharding rules: divisibility of every param/cache spec for every arch on
the production meshes, rule resolution, and the collectives math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_config
from repro.dist.sharding import Rules, param_spec_for, param_specs
from repro.dist.collectives import (compressed_psum, dequantize_int8,
                                    quantize_int8, zeros_like_errors)
from repro.models import init_params


class FakeMesh:
    """Shape-only stand-in (no jax devices needed for spec math)."""
    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.zeros(shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible_on_production_mesh(arch):
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    specs = param_specs(params, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def check(path, leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axs = (ax,) if isinstance(ax, str) else ax
            n = 1
            for a in axs:
                n *= sizes[a]
            assert dim % n == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params, specs,
        is_leaf=lambda x: hasattr(x, "shape"))


def test_param_rules_hit_expected_axes():
    spec = param_spec_for("layers/attn/wqkv", 3, True, (16, 2048, 3072),
                          FakeMesh((16, 16), ("data", "model")))
    assert spec == P(None, "data", "model")
    spec = param_spec_for("embed/tok", 2, False, (4096, 128),
                          FakeMesh((16, 16), ("data", "model")))
    assert spec == P("model", "data")
    # whisper vocab not divisible by 16 -> axis dropped
    spec = param_spec_for("embed/tok", 2, False, (51865, 768),
                          FakeMesh((16, 16), ("data", "model")))
    assert spec == P(None, "data")
    # norms replicated
    assert param_spec_for("layers/ln1", 2, True) == P(None, None)


def test_rules_kinds():
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    train = Rules(mesh, "train")
    assert train.spec("batch", None) == P(("pod", "data"), None)
    dec = Rules(mesh, "decode")
    assert dec.map["cache_seq"] == "model"
    lng = Rules(mesh, "long")
    assert lng.map["batch"] is None
    assert lng.map["cache_seq"] == ("pod", "data", "model")


def test_dryrun_cells_cover_assignment():
    """40 cells total; 33 runnable; skips are exactly the documented ones."""
    total = runnable = 0
    for a in ARCH_IDS:
        for s in SHAPES:
            total += 1
            ok, why = cell_is_runnable(get_config(a), s)
            runnable += ok
            if not ok:
                assert "sub-quadratic" in why
    assert total == 40 and runnable == 33


# ---------------------------------------------------------------------------
# compressed gradient collectives
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    x = jnp.linspace(-3, 3, 1000)
    scale = jnp.max(jnp.abs(x))
    err = np.asarray(x - dequantize_int8(quantize_int8(x, scale), scale))
    assert np.max(np.abs(err)) <= float(scale) / 127 + 1e-6


def test_compressed_psum_single_device_exact_with_error_feedback():
    """On a 1-device mesh psum is identity; error feedback must capture the
    quantization residual so that value+err reconstructs the input."""
    mesh = jax.make_mesh((1,), ("pod",))
    x = jnp.array([0.1, -2.5, 3.14159, 0.0])
    err0 = jnp.zeros_like(x)

    from jax.experimental.shard_map import shard_map
    f = shard_map(lambda a, e: compressed_psum(a, e, "pod"), mesh=mesh,
                  in_specs=(P(), P()), out_specs=(P(), P()))
    y, err = f(x, err0)
    assert np.allclose(np.asarray(y + err), np.asarray(x), atol=1e-6)
    # next round with error feedback converges toward exact
    y2, err2 = f(x - y + y, err)     # same gradient again
    total = np.asarray(y) + np.asarray(y2)
    assert np.allclose(total / 2, np.asarray(x), atol=float(jnp.max(jnp.abs(x))) / 127)


def test_compressed_tree_psum_shapes():
    from repro.dist.collectives import compressed_tree_psum
    mesh = jax.make_mesh((1,), ("pod",))
    from jax.experimental.shard_map import shard_map
    g = {"a": jnp.ones((4, 4)), "b": {"c": jnp.full((3,), -2.0)}}
    e = zeros_like_errors(g)
    f = shard_map(lambda gg, ee: compressed_tree_psum(gg, ee, "pod"), mesh=mesh,
                  in_specs=(P(), P()), out_specs=(P(), P()))
    out, err = f(g, e)
    assert jax.tree.structure(out) == jax.tree.structure(g)
    assert np.allclose(np.asarray(out["a"] + err["a"]), 1.0, atol=1e-6)
