"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.integrity import fletcher64
from repro.core.cost import job_cost, PAPER_ENVS
from repro.kernels.checksum import device_checksum, device_checksum_ref
from repro.analysis.hlo_parse import split_computations, HloCosts

import jax.numpy as jnp


@given(st.binary(min_size=0, max_size=2048))
@settings(max_examples=50, deadline=None)
def test_fletcher64_deterministic_and_padded(data):
    a = fletcher64(data)
    assert a == fletcher64(data)
    assert 0 <= a < 2 ** 64


@given(st.binary(min_size=4, max_size=512), st.integers(0, 3))
@settings(max_examples=50, deadline=None)
def test_fletcher64_sensitive_to_flips(data, pos):
    flipped = bytearray(data)
    flipped[pos % len(data)] ^= 0x01
    if bytes(flipped) != data:
        assert fletcher64(data) != fletcher64(bytes(flipped))


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=1, max_size=300))
@settings(max_examples=25, deadline=None)
def test_device_checksum_matches_ref(xs):
    arr = np.asarray(xs, np.float32)
    got = np.asarray(device_checksum(jnp.asarray(arr), interpret=True))
    ref = device_checksum_ref(arr)
    assert np.array_equal(got, ref)


@given(st.integers(1, 1000), st.floats(1.0, 600.0), st.floats(0.01, 100.0))
@settings(max_examples=50, deadline=None)
def test_job_cost_monotone(n_jobs, minutes, gb):
    """More jobs / longer jobs never cost less; cloud >= hpc per-hour."""
    for env in PAPER_ENVS.values():
        c1 = job_cost(env, n_jobs, minutes, gb)
        c2 = job_cost(env, n_jobs + 1, minutes, gb)
        c3 = job_cost(env, n_jobs, minutes * 2, gb)
        assert c2["dollars"] >= c1["dollars"]
        assert c3["dollars"] >= c1["dollars"]


@given(st.integers(1, 64), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_hlo_loop_multiplication(trips, nbytes_mb):
    """Synthetic HLO: collective inside a while body is multiplied by the
    trip count inferred from the condition."""
    n = nbytes_mb * 262144     # f32 elements per MB
    hlo = f"""
cond {{
  p = (s32[]) parameter(0)
  i = s32[] get-tuple-element(p), index=0
  t = s32[] constant({trips})
  ROOT lt = pred[] compare(i, t), direction=LT
}}

body {{
  p = (s32[]) parameter(0)
  ar = f32[{n}] all-reduce(x), to_apply=add
  ROOT out = (s32[]) tuple(i)
}}

ENTRY main {{
  w = (s32[]) while(init), condition=cond, body=body
  ROOT r = s32[] get-tuple-element(w), index=0
}}
"""
    costs = HloCosts(hlo)
    got = costs.collective_bytes()
    assert got["per_op"]["all-reduce"] == trips * n * 4


def test_split_computations_basic():
    hlo = "comp_a {\n  x = f32[2] parameter(0)\n}\n\nENTRY main {\n  y = f32[2] constant(0)\n}\n"
    comps = split_computations(hlo)
    assert set(comps) == {"comp_a", "main"}


@given(n_subjects=st.integers(1, 4), sessions=st.integers(1, 2),
       nodes=st.integers(1, 3), flaky=st.booleans(),
       die=st.integers(0, 3), harass_peers=st.booleans())
@settings(max_examples=8, deadline=None)
def test_cluster_exactly_one_ok_provenance_and_no_torn_files(
        n_subjects, sessions, nodes, flaky, die, harass_peers):
    """Distributed-executor invariant: for random unit lists, node counts and
    injected failures (transient faults + one node death), every unit ends
    with exactly one committed ok provenance, and a concurrent reader NEVER
    observes a partial output file or torn provenance (atomic tmp+rename).
    ``harass_peers`` additionally runs the blob fabric under hostile peers
    (dead addrs, corrupted bodies, Bloom false positives) — every peer
    failure must fall back to storage without disturbing the invariant.
    Body shared with the deterministic sweep in test_cluster.py."""
    from cluster_invariant import check_cluster_invariant
    check_cluster_invariant(n_subjects, sessions, nodes, flaky, die,
                            harass_peers=harass_peers)


@st.composite
def _dag_edges(draw, max_units=8):
    """Random acyclic ``{child_pos: [parent_pos, ...]}`` topologies: every
    parent index is strictly smaller than its child, so chains, diamonds and
    fan-in gates all appear but cycles cannot. Positions past the actual
    unit count are dropped by the harness's normalization."""
    edges = {}
    for c in range(1, max_units):
        ps = draw(st.lists(st.integers(0, c - 1), max_size=2, unique=True))
        if ps:
            edges[c] = sorted(ps)
    return edges


@given(n_subjects=st.integers(2, 4), nodes=st.integers(1, 3),
       flaky=st.booleans(), die=st.integers(0, 2), edges=_dag_edges(),
       fail=st.one_of(st.none(), st.integers(0, 7)))
@settings(max_examples=8, deadline=None)
def test_cluster_dag_gating_and_failure_propagation(
        n_subjects, nodes, flaky, die, edges, fail):
    """DAG extension of the executor invariant, over random topologies
    (chains, diamonds, fan-in gates) with chaos (transient faults, node
    death) and optionally one permanently failing unit: runnable units end
    with exactly one ok provenance, no child's provenance predates its last
    parent's commit, and a failed unit's transitive descendants end
    terminally ``blocked`` — no provenance, no output dir, surfaced in
    ``stats_snapshot()['dag']``. Body shared with the deterministic grid in
    test_dag.py / test_cluster.py."""
    from cluster_invariant import check_cluster_invariant
    check_cluster_invariant(n_subjects, 2, nodes, flaky, die,
                            dag_edges=edges, fail_idx=fail)


_DIGEST_POOL = [f"d{i}" for i in range(12)]


@st.composite
def _cohorts_and_summaries(draw):
    """Arbitrary campaign inputs: 1-3 cohorts of synthetic work units (0-2
    input digests each, drawn from a small pool so summaries genuinely
    overlap), exclusion lists that may name admitted sessions, an optional
    re-submitted duplicate cohort, and 0-3 per-node digest summaries."""
    import dataclasses
    from repro.core.campaign import Cohort
    from repro.core.query import Exclusion, WorkUnit
    from repro.dist import DigestSummary
    cohorts = []
    for c in range(draw(st.integers(1, 3))):
        units = []
        for i in range(draw(st.integers(0, 8))):
            digs = draw(st.lists(st.sampled_from(_DIGEST_POOL),
                                 max_size=2, unique=True))
            size = draw(st.integers(0, 1 << 16))
            units.append(WorkUnit(
                dataset=f"ds{c}", subject=f"s{i:02d}", session="01",
                pipeline="p", pipeline_digest="pd",
                inputs={f"in{k}": f"{i}-{k}.npy" for k in range(len(digs))},
                out_dir=f"/out/ds{c}/{i}",
                input_digests={f"in{k}": d for k, d in enumerate(digs)},
                input_bytes={f"in{k}": size for k in range(len(digs))}))
        # sprinkle depends_on edges onto later units (parents always earlier
        # in admission order, so the random DAG is acyclic by construction;
        # an excluded parent exercises the absent-parent-is-satisfied rule)
        for i in range(1, len(units)):
            ps = draw(st.lists(st.integers(0, i - 1), max_size=2,
                               unique=True))
            units[i].depends_on = [units[p].job_id for p in ps]
        excluded = [Exclusion(f"s{draw(st.integers(0, 9)):02d}", "01", "x")
                    for _ in range(draw(st.integers(0, 3)))]
        cohorts.append(Cohort(f"ds{c}", "p", "pd", units, excluded))
    if draw(st.booleans()):                      # overlapping re-submission
        cohorts.append(dataclasses.replace(cohorts[0]))
    summaries = {}
    for n in range(draw(st.integers(0, 3))):
        s = DigestSummary(m=512, k=3)
        for d in draw(st.lists(st.sampled_from(_DIGEST_POOL),
                               max_size=6, unique=True)):
            s.add(d)
        summaries[f"n{n}"] = s
    throttle = draw(st.integers(1, 64))
    status = {"disk_free_gb": draw(st.floats(0.0, 64.0, allow_nan=False))}
    max_shard = draw(st.one_of(st.none(), st.integers(1, 4)))
    return cohorts, summaries, throttle, status, max_shard


@given(_cohorts_and_summaries())
@settings(max_examples=40, deadline=None)
def test_campaign_plan_exactly_once_no_excluded_byte_replayable(case):
    """Campaign-planner invariant: for arbitrary cohorts and summary states,
    every admitted unit is assigned to exactly one shard, a unit its cohort
    excluded is never assigned, replanning — in memory and through the
    serialized campaign.json — is byte-identical, and a DAG child whose only
    warmth is its parents' predicted outputs is producer-placed onto the
    parents' node (the admission-time twin of the executor invariant below;
    body shared with the deterministic grid in test_campaign.py)."""
    from campaign_invariant import check_campaign_invariant
    cohorts, summaries, throttle, status, max_shard = case
    check_campaign_invariant(cohorts, summaries, throttle=throttle,
                             status=status, max_shard_units=max_shard)


@given(st.integers(2, 16), st.integers(2, 8), st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_moe_dispatch_conservation(S, E, C):
    """Scatter-dispatch: every kept token appears exactly once in the buffer;
    combine-gather reconstructs identity when experts are identity."""
    import jax
    from repro.models.moe import _dispatch_seq
    key = jax.random.PRNGKey(S * 100 + E * 10 + C)
    x = jax.random.normal(key, (S, 4))
    sel = jax.random.randint(key, (S, 1), 0, E)
    w = jnp.ones((S, 1))
    buf, idx, keep = _dispatch_seq(x, sel, w, E, C)
    # gather back the kept tokens: must equal the originals
    kept = np.asarray(keep)[:, 0]
    got = np.asarray(buf)[np.asarray(idx)[:, 0][kept]]
    want = np.asarray(x)[kept]
    assert np.allclose(got, want, atol=1e-6)
    # buffer rows not pointed to by any kept slot are zero
    used = set(np.asarray(idx)[:, 0][kept].tolist())
    for row in range(E * C):
        if row not in used:
            assert np.allclose(np.asarray(buf)[row], 0.0)


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=True, allow_infinity=True,
                          width=32),
                min_size=0, max_size=600),
       st.integers(1, 3000),
       st.sampled_from(["float32", "float16", "int16", "uint8"]))
@settings(max_examples=40, deadline=None)
def test_chunked_qa_fold_bit_exact_vs_one_shot(xs, chunk, dtype):
    """Streaming ingest invariant (repro.core.stream): feeding a volume's
    bytes through the chunk-accumulating fused QA+checksum fold in ANY
    chunking — including chunk > volume and non-dividing tails — must be
    bit-identical to the one-shot kernel. (Deterministic slice of this sweep
    lives in test_stream.py for environments without hypothesis.)"""
    from repro.kernels.checksum import QAChecksumAccumulator, qa_stats
    arr = np.asarray(xs, np.float32)
    if dtype != "float32":
        with np.errstate(invalid="ignore", over="ignore"):
            arr = arr.astype(dtype)
    acc = QAChecksumAccumulator(arr.size, arr.dtype, interpret=True)
    data = arr.tobytes()
    for off in range(0, max(len(data), 1), chunk):
        acc.update(data[off:off + chunk])
    assert acc.finalize() == qa_stats(arr, interpret=True)
