"""Tiered storage, integrity, and checkpoint/restart (incl. elastic restore)."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IntegrityError, TieredStore, fletcher64, verified_copy
from repro.core.cost import paper_table1, cost_ratio_cloud_vs_hpc
from repro.ckpt import (CheckpointManager, latest_step, restore_checkpoint,
                        save_checkpoint)


def test_fletcher64_properties():
    a = np.arange(100, dtype=np.float32)
    assert fletcher64(a) == fletcher64(a.copy())
    b = a.copy()
    b[3] += 1
    assert fletcher64(a) != fletcher64(b)


def test_verified_copy_and_corruption(tmp_path):
    src = tmp_path / "a.bin"
    src.write_bytes(b"hello world" * 100)
    dst = tmp_path / "b.bin"
    digest = verified_copy(src, dst)
    assert dst.read_bytes() == src.read_bytes()
    assert len(digest) == 64


def test_tiered_store_roundtrip_and_costs(tmp_path):
    store = TieredStore(tmp_path / "store")
    f = tmp_path / "data.npy"
    np.save(f, np.arange(1000))
    store.put(f, "ds/data.npy", tier="hot")
    out = tmp_path / "back.npy"
    store.get("ds/data.npy", out, tier="hot")
    assert np.array_equal(np.load(out), np.arange(1000))
    store.archive_to_cold("ds/data.npy")
    assert store.exists("ds/data.npy", tier="cold")
    costs = store.storage_cost_per_year()
    assert costs["cold"] < costs["hot"]          # Glacier is cheaper
    assert store.log["hot"].n_transfers >= 2
    assert store.log["hot"].simulated_seconds > 0


def test_secure_tier_authorization(tmp_path):
    f = tmp_path / "x.npy"
    np.save(f, np.zeros(4))
    store = TieredStore(tmp_path / "s", authorized_secure=False)
    with pytest.raises(PermissionError):
        store.put(f, "gdpr/x.npy", tier="secure")
    store2 = TieredStore(tmp_path / "s2", authorized_secure=True)
    store2.put(f, "gdpr/x.npy", tier="secure")
    link = store2.link_secure_into_general("gdpr/x.npy")
    assert link.is_symlink()                      # paper's symlink arrangement
    assert np.array_equal(np.load(link), np.zeros(4))


def test_paper_table1_reproduction():
    t = paper_table1()
    # paper: $0.36 HPC vs $6.59 AWS vs $3.53 local — ~20x cloud/HPC ratio
    assert abs(t["hpc"]["total_cost"] - 0.36) < 0.03
    assert abs(t["cloud"]["total_cost"] - 6.59) < 0.1
    assert abs(t["local"]["total_cost"] - 3.53) < 0.1
    assert 17 < cost_ratio_cloud_vs_hpc() < 20


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------

def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones(5, jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 10, tree, digest="abc", extra={"loss": 1.5})
    restored, step, extra = restore_checkpoint(tmp_path, jax.eval_shape(lambda: tree))
    assert step == 10 and extra["loss"] == 1.5
    assert np.array_equal(restored["w"], np.asarray(tree["w"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_corruption_detected(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    step_dir = tmp_path / "step_00000001"
    victim = next(p for p in step_dir.glob("*.npy"))
    arr = np.load(victim)
    if arr.dtype.kind == "V":      # bf16 leaves round-trip as raw void16
        arr = arr.view(np.uint16)
    arr = arr.copy().astype(arr.dtype)
    flat = arr.reshape(-1).copy()
    flat[0] = flat[0] + (1 if np.issubdtype(arr.dtype, np.integer) else 0.5)
    np.save(victim, flat.reshape(arr.shape))
    with pytest.raises(IntegrityError):
        restore_checkpoint(tmp_path, jax.eval_shape(_tree))


def test_checkpoint_manager_async_retention_and_archive(tmp_path):
    store = TieredStore(tmp_path / "store")
    mgr = CheckpointManager(tmp_path / "ckpt", keep=2, cold_store=store)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(), extra={"s": s})
    mgr.wait()
    assert latest_step(tmp_path / "ckpt") == 4
    steps = sorted(p.name for p in (tmp_path / "ckpt").glob("step_*"))
    assert len(steps) == 2                        # retention
    assert store.exists("ckpt/step_00000004/manifest.json", tier="cold")


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint written unsharded restores onto an explicit 1-device mesh
    sharding (the elastic path: restart on a different mesh)."""
    tree = _tree()
    save_checkpoint(tmp_path, 5, tree)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored, step, _ = restore_checkpoint(tmp_path, jax.eval_shape(lambda: tree),
                                           shardings=sh)
    assert step == 5
    assert restored["w"].sharding == NamedSharding(mesh, P())


def test_restart_resumes_training_state(tmp_path):
    """Simulated node failure: training state restored bit-identical."""
    from repro.configs import get_config
    from repro.train import OptConfig, init_train_state, make_train_step
    from repro.data import make_lm_batches
    cfg = get_config("llama3.2-1b").reduced()
    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3, warmup_steps=1)))
    batches = make_lm_batches(cfg, 2, 32, 4)
    for b in batches[:2]:
        params, opt_state, _ = step_fn(params, opt_state, b)
    save_checkpoint(tmp_path, 2, {"params": params, "opt": opt_state})
    # "crash"; restore and continue
    tmpl = jax.eval_shape(lambda: {"params": params, "opt": opt_state})
    restored, step, _ = restore_checkpoint(tmp_path, tmpl)
    p2, o2 = restored["params"], restored["opt"]
    a1, _, m1 = step_fn(params, opt_state, batches[2])
    a2, _, m2 = step_fn(jax.tree.map(jnp.asarray, p2),
                        jax.tree.map(jnp.asarray, o2), batches[2])
    assert np.allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-6)
