"""Dependency-aware campaign DAGs: queue gating protocol, serialization /
rpc version skew, and staged end-to-end cluster runs.

The queue-level contract under test: a unit with ``depends_on`` is *parked*
— invisible to every grant path (own deque, backlog fill, stealing,
speculation) — until every in-queue parent has retired ``ok``/``skipped``.
A parent that fails terminally cascades every transitive descendant to a
terminal ``blocked`` status instead. Reaped/dead parents release nothing:
only a committed retirement does.
"""
import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Dict

import numpy as np
import pytest

from repro.core import (Provenance, builtin_pipelines, query_available_work,
                        synthesize_dataset)
from repro.core.query import WorkUnit, dump_units, load_units
from repro.core.workflow import WRITE_THROUGH_ENV, run_unit
from repro.dist import ClusterRunner, WorkQueue
from repro.dist.cache import InputCache
from repro.dist.rpc import _decode, _encode


def _unit(tag: str, deps=(), pipeline: str = "p") -> WorkUnit:
    return WorkUnit(dataset="dag", subject=tag, session="01",
                    pipeline=pipeline, pipeline_digest="pd",
                    inputs={"T1w": f"in/{tag}.npy"}, out_dir=f"/out/{tag}",
                    depends_on=[d.job_id if isinstance(d, WorkUnit) else d
                                for d in deps])


def _drain(q: WorkQueue, node: str):
    """Grant everything currently leasable to ``node``."""
    got = []
    while True:
        nxt = q.next_unit(node)
        if nxt is None:
            return got
        got.append(nxt)


# ---------------------------------------------------------------------------
# queue gating protocol
# ---------------------------------------------------------------------------

def test_chain_grants_strictly_in_order():
    a = _unit("a")
    b = _unit("b", deps=[a])
    c = _unit("c", deps=[b])
    q = WorkQueue([a, b, c], ["n0"])
    grants = _drain(q, "n0")
    assert [u.job_id for u, _ in grants] == [a.job_id]   # only the root
    q.complete(0, "n0", "ok")
    grants = _drain(q, "n0")
    assert [u.job_id for u, _ in grants] == [b.job_id]
    q.complete(1, "n0", "ok")
    (u, _), = _drain(q, "n0")
    assert u.job_id == c.job_id
    q.complete(2, "n0", "ok")
    assert q.finished()


def test_diamond_child_needs_both_parents_and_is_granted_once():
    root = _unit("r")
    left = _unit("l", deps=[root])
    right = _unit("g", deps=[root])
    sink = _unit("s", deps=[left, right])
    q = WorkQueue([root, left, right, sink], ["n0", "n1"])
    idx = {u.job_id: i for i, u in enumerate([root, left, right, sink])}
    (u, lease), = _drain(q, "n0") + _drain(q, "n1")
    assert u.job_id == root.job_id
    q.complete(lease.unit_idx, lease.node_id, "ok")
    mids = _drain(q, "n0") + _drain(q, "n1")
    assert sorted(u.job_id for u, _ in mids) == sorted(
        [left.job_id, right.job_id])
    # one parent done: the sink must stay parked
    q.complete(idx[left.job_id], "n0", "ok")
    assert _drain(q, "n0") + _drain(q, "n1") == []
    q.complete(idx[right.job_id], "n1", "ok")
    sinks = _drain(q, "n0") + _drain(q, "n1")
    assert [u.job_id for u, _ in sinks] == [sink.job_id]


def test_parked_child_is_invisible_to_steal_and_speculation():
    a = _unit("a")
    b = _unit("b", deps=[a])
    q = WorkQueue([a, b], ["busy", "idle"])
    # between stealing and backlog fill, both nodes combined can surface
    # only the root — the parked child is on no deque to be stolen from
    granted = _drain(q, "idle") + _drain(q, "busy")
    assert {u.job_id for u, _ in granted} == {a.job_id}
    # nor can the straggler path lease the parked child as a twin
    assert q.speculate(1, "idle") is None
    assert q.speculate(1, "busy") is None


def test_failed_parent_blocks_all_descendants_terminally():
    a = _unit("a")
    b = _unit("b", deps=[a])
    c = _unit("c", deps=[b])
    d = _unit("d")                                      # independent bystander
    q = WorkQueue([a, b, c, d], ["n0"])
    grants = {u.job_id: l for u, l in _drain(q, "n0")}
    assert set(grants) == {a.job_id, d.job_id}          # only the roots
    q.complete(grants[a.job_id].unit_idx, "n0", "failed")
    assert q.done_status()[1] == "blocked"
    assert q.done_status()[2] == "blocked"              # transitive
    # blocked units are terminal: never granted, and the queue can finish
    assert _drain(q, "n0") == []
    q.complete(grants[d.job_id].unit_idx, "n0", "ok")
    assert q.finished()
    dag = q.stats_snapshot()["dag"]
    assert dag["cancelled"] == 2 and dag["blocked"] == 0 and dag["ready"] == 0


def test_reaped_parent_re_blocks_child_until_rerun_commits():
    t = {"now": 0.0}
    a = _unit("a")
    b = _unit("b", deps=[a])
    q = WorkQueue([a, b], ["n0", "n1"], lease_ttl_s=1.0,
                  now=lambda: t["now"])
    granted = _drain(q, "n0") + _drain(q, "n1")
    assert [u.job_id for u, _ in granted] == [a.job_id]
    (_, lease), = granted
    holder, other = lease.node_id, ("n1" if lease.node_id == "n0" else "n0")
    # the holder goes silent past the TTL: the parent is reaped and requeued,
    # and the child must stay parked — a reaped parent committed nothing
    t["now"] = 1.5
    q.heartbeat(other)
    assert lease.unit_idx in q.reap()
    regrants = _drain(q, other)
    assert [u.job_id for u, _ in regrants] == [a.job_id]   # parent, not child
    (_, lease2), = regrants
    assert lease2.epoch > lease.epoch
    # a zombie completion from the dead holder still releases nothing
    q.complete(lease.unit_idx, holder, "ok")
    assert _drain(q, other) == []
    # the live re-run's commit finally releases the child
    q.complete(lease2.unit_idx, other, "ok")
    (u, _), = _drain(q, other)
    assert u.job_id == b.job_id


def test_child_released_to_dead_home_lands_in_backlog():
    a = _unit("a")
    b = _unit("b", deps=[a])
    others = [_unit(f"x{i}") for i in range(2)]
    q = WorkQueue([a, b] + others, ["n0", "n1"])
    # find and finish the parent from whichever deque holds it, then kill
    # the child's planned home before release
    grants = {u.job_id: l for u, l in _drain(q, "n0") + _drain(q, "n1")}
    child_home = "n1" if grants[a.job_id].node_id == "n0" else "n1"
    q.mark_dead(child_home)
    q.complete(grants[a.job_id].unit_idx, grants[a.job_id].node_id, "ok")
    alive = "n0" if child_home == "n1" else "n1"
    # the child is grantable to the surviving node (via backlog), not lost
    released = _drain(q, alive)
    assert b.job_id in {u.job_id for u, _ in released}


def test_cycle_and_self_dependency_are_rejected_at_construction():
    a = _unit("a")
    b = _unit("b", deps=[a])
    a.depends_on = [b.job_id]
    with pytest.raises(ValueError, match="cycle"):
        WorkQueue([a, b], ["n0"])
    s = _unit("s")
    s.depends_on = [s.job_id]
    with pytest.raises(ValueError, match="cycle"):
        WorkQueue([s], ["n0"])


def test_absent_parent_counts_as_satisfied():
    b = _unit("b", deps=["dag_p_sub-finished-long-ago_ses-01"])
    q = WorkQueue([b], ["n0"])
    (u, _), = _drain(q, "n0")
    assert u.job_id == b.job_id


def test_stats_snapshot_reports_per_stage_progress():
    s1 = [_unit(f"a{i}", pipeline="stage1") for i in range(3)]
    s2 = [_unit(f"b{i}", deps=[s1[i]], pipeline="stage2") for i in range(3)]
    q = WorkQueue(s1 + s2, ["n0"])
    dag = q.stats_snapshot()["dag"]
    assert dag == {"ready": 3, "blocked": 3, "cancelled": 0,
                   "per_stage": dag["per_stage"]}
    assert dag["per_stage"]["stage1"]["ready"] == 3
    assert dag["per_stage"]["stage2"]["blocked"] == 3
    grants = {u.job_id: l for u, l in _drain(q, "n0")}
    q.complete(grants[s1[0].job_id].unit_idx, "n0", "ok")
    q.complete(grants[s1[1].job_id].unit_idx, "n0", "failed")
    dag = q.stats_snapshot()["dag"]
    assert dag["per_stage"]["stage1"] == {
        "total": 3, "ok": 1, "failed": 1, "cancelled": 0, "blocked": 0,
        "ready": 1}
    assert dag["per_stage"]["stage2"] == {
        "total": 3, "ok": 0, "failed": 0, "cancelled": 1, "blocked": 1,
        "ready": 1}


# ---------------------------------------------------------------------------
# serialization + version skew
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _LegacyWorkUnit:
    """The pre-DAG WorkUnit schema, frozen here as the backcompat oracle:
    what an old coordinator's ``load_units`` would construct."""
    dataset: str
    subject: str
    session: str
    pipeline: str
    pipeline_digest: str
    inputs: Dict[str, str]
    out_dir: str
    input_digests: Dict[str, str] = dataclasses.field(default_factory=dict)
    input_bytes: Dict[str, int] = dataclasses.field(default_factory=dict)


def test_dump_load_round_trips_depends_on(tmp_path):
    a = _unit("a")
    b = _unit("b", deps=[a])
    path = dump_units([a, b], tmp_path / "units.json")
    back = load_units(path)
    assert back == [a, b]
    assert back[1].depends_on == [a.job_id]


def test_plain_units_serialize_in_the_exact_pre_dag_shape(tmp_path):
    a = _unit("a")
    path = dump_units([a], tmp_path / "units.json")
    rows = json.loads(path.read_text())
    assert "depends_on" not in rows[0]
    # an old loader accepts them unchanged...
    legacy = [_LegacyWorkUnit(**r) for r in rows]
    assert legacy[0].out_dir == a.out_dir
    # ...and a pre-DAG units file loads here as independent units
    q = WorkQueue(load_units(path), ["n0"])
    assert len(_drain(q, "n0")) == 1


def test_old_coordinator_rejects_dag_units_loudly(tmp_path):
    a = _unit("a")
    b = _unit("b", deps=[a])
    rows = json.loads(dump_units([a, b], tmp_path / "u.json").read_text())
    with pytest.raises(TypeError, match="depends_on"):
        [_LegacyWorkUnit(**r) for r in rows]


def test_rpc_wire_carries_deps_in_a_sidecar_old_decoders_shed():
    a = _unit("a")
    b = _unit("b", deps=[a])
    enc = _encode(b)
    assert enc["__deps__"] == [a.job_id]
    assert "depends_on" not in enc["__unit__"]
    assert _decode(enc) == b                     # new decoder restores edges
    # an old decoder reads only __unit__: the unit arrives dependency-free —
    # safe, because a coordinator only ever sends *ready* units to workers
    shed = WorkUnit(**enc["__unit__"])
    assert shed.depends_on == [] and shed.job_id == b.job_id
    legacy = _LegacyWorkUnit(**enc["__unit__"])  # even the pre-DAG dataclass
    assert legacy.out_dir == b.out_dir
    # independent units stay byte-identical to the pre-DAG wire shape
    assert "__deps__" not in _encode(a)
    assert _decode(_encode(a)) == a


# ---------------------------------------------------------------------------
# staged end-to-end cluster runs
# ---------------------------------------------------------------------------

@pytest.fixture()
def dataset(tmp_path):
    return synthesize_dataset(tmp_path, "dagds", n_subjects=4,
                              sessions_per_subject=1, shape=(8, 8, 8))


def _staged_units(dataset):
    """Stage 1: bias_correct from the manifest. Stage 2: affine_register
    consuming each session's stage-1 ``T1w_biascorr`` output — a real
    mixed-pipeline DAG (inputs that do not exist until the parent commits)."""
    pipes = builtin_pipelines()
    s1, _ = query_available_work(dataset, pipes["bias_correct"])
    s2 = []
    for u in s1:
        rel = (f"derivatives/bias_correct/sub-{u.subject}/ses-{u.session}/"
               f"sub-{u.subject}_ses-{u.session}_T1w_biascorr.npy")
        s2.append(WorkUnit(
            dataset=u.dataset, subject=u.subject, session=u.session,
            pipeline="affine_register",
            pipeline_digest=pipes["affine_register"].digest(),
            inputs={"T1w": rel},
            out_dir=str(Path(dataset.root) / "derivatives" /
                        "affine_register" / f"sub-{u.subject}" /
                        f"ses-{u.session}"),
            depends_on=[u.job_id]))
    return pipes, s1, s2


def test_staged_pipelines_run_end_to_end_in_one_queue(dataset):
    pipes, s1, s2 = _staged_units(dataset)
    runner = ClusterRunner(pipes, dataset.root, nodes=3)
    results = runner.run(s1 + s2)
    assert sum(r.status == "ok" for r in results) == len(s1) + len(s2)
    for parent, child in zip(s1, s2):
        pp = Provenance.load(Path(parent.out_dir))
        cp = Provenance.load(Path(child.out_dir))
        assert pp.status == "ok" and cp.status == "ok"
        # no child ran before its parent's commit
        assert cp.started_at >= pp.finished_at - 1e-6
        # the child consumed the exact bytes the parent committed
        assert cp.inputs[child.inputs["T1w"]] == pp.outputs[
            f"sub-{parent.subject}_ses-{parent.session}_T1w_biascorr.npy"]


def test_staged_run_with_node_death_still_orders_correctly(dataset):
    pipes, s1, s2 = _staged_units(dataset)
    runner = ClusterRunner(pipes, dataset.root, nodes=3,
                           die_after={"node-1": 1},
                           lease_ttl_s=0.5, hb_interval_s=0.1)
    results = runner.run(s1 + s2)
    assert sum(r.status == "ok" for r in results) == len(s1) + len(s2)
    for parent, child in zip(s1, s2):
        pp = Provenance.load(Path(parent.out_dir))
        cp = Provenance.load(Path(child.out_dir))
        assert cp.started_at >= pp.finished_at - 1e-6


def test_failed_stage_blocks_children_at_the_cluster_level(dataset):
    pipes, s1, s2 = _staged_units(dataset)
    poisoned = s1[0].job_id

    def poison(unit, attempt):
        if unit.job_id == poisoned:
            raise RuntimeError("synthetic stage-1 failure")

    runner = ClusterRunner(pipes, dataset.root, nodes=2, max_retries=1,
                           fault_hook=poison)
    results = runner.run(s1 + s2)
    by_id = {}
    for r in results:                  # primary result per unit, not twins
        if r.status != "speculative":
            by_id.setdefault(r.unit.job_id, r)
    assert by_id[poisoned].status == "failed"
    blocked = by_id[s2[0].job_id]
    assert blocked.status == "blocked"
    assert "depends_on" in (blocked.error or "")
    assert Provenance.load(Path(s2[0].out_dir)) is None  # never started
    # every other lineage completed untouched
    for parent, child in zip(s1[1:], s2[1:]):
        assert by_id[parent.job_id].status == "ok"
        assert by_id[child.job_id].status == "ok"


def test_unit_naming_unknown_pipeline_fails_without_crashing_node(dataset):
    pipes, s1, _ = _staged_units(dataset)
    bad = dataclasses.replace(s1[0], pipeline="no_such_stage",
                              out_dir=s1[0].out_dir + "-bad")
    runner = ClusterRunner({"bias_correct": pipes["bias_correct"]},
                           dataset.root, nodes=2)
    results = runner.run(s1[1:] + [bad])
    by_id = {r.unit.job_id: r for r in results}
    assert by_id[bad.job_id].status == "failed"
    assert "no_such_stage" in by_id[bad.job_id].error
    assert all(by_id[u.job_id].status == "ok" for u in s1[1:])


# ---------------------------------------------------------------------------
# deterministic invariant sweep (shared harness; the hypothesis twin draws
# random topologies in test_property.py)
# ---------------------------------------------------------------------------

# chain, diamond, two-stage fan-in QC gate — the canonical shapes
_TOPOLOGIES = {
    "chain": {1: [0], 2: [1], 3: [2]},
    "diamond": {1: [0], 2: [0], 3: [1, 2]},
    "fanin_gate": {4: [0, 1], 5: [2, 3], 6: [4, 5], 7: [4, 5]},
}


@pytest.mark.parametrize("topology", sorted(_TOPOLOGIES))
@pytest.mark.parametrize("fail_idx", [None, 0])
def test_dag_invariant_deterministic(topology, fail_idx):
    from cluster_invariant import check_cluster_invariant
    check_cluster_invariant(4, 2, 3, False, 0,
                            dag_edges=_TOPOLOGIES[topology],
                            fail_idx=fail_idx)


def test_dag_invariant_under_chaos():
    """The full gauntlet on a diamond: transient faults, one node death and
    a permanently failing root at once — gating and blocked-propagation must
    hold while leases are reaped and re-granted."""
    from cluster_invariant import check_cluster_invariant
    check_cluster_invariant(4, 2, 3, True, 1,
                            dag_edges=_TOPOLOGIES["diamond"], fail_idx=2)


# ---------------------------------------------------------------------------
# output write-through (producer placement's data plane)
# ---------------------------------------------------------------------------

def test_committed_outputs_are_written_through_to_the_cache(dataset,
                                                            tmp_path):
    pipes = builtin_pipelines()
    units, _ = query_available_work(dataset, pipes["bias_correct"])
    cache = InputCache(tmp_path / "cache")
    res = run_unit(units[0], pipes["bias_correct"], dataset.root, cache=cache)
    assert res.status == "ok"
    prov = Provenance.load(Path(units[0].out_dir))
    for name, digest in prov.outputs.items():
        blob = cache.read_blob(digest)
        assert blob is not None
        assert hashlib.sha256(blob).hexdigest() == digest


def test_write_through_env_kill_switch(dataset, tmp_path, monkeypatch):
    monkeypatch.setenv(WRITE_THROUGH_ENV, "0")
    pipes = builtin_pipelines()
    units, _ = query_available_work(dataset, pipes["bias_correct"])
    cache = InputCache(tmp_path / "cache")
    res = run_unit(units[0], pipes["bias_correct"], dataset.root, cache=cache)
    assert res.status == "ok"
    prov = Provenance.load(Path(units[0].out_dir))
    assert all(cache.read_blob(d) is None for d in prov.outputs.values())
