"""Locality-aware scheduling: digest summaries, affinity-scored grants /
backlog fills / steals / speculation / dead-node requeues, the placement
counters in ``stats_snapshot``, version-skew fail-soft, and the
``InputCache`` compaction-crash recovery — the placement-policy layer of
``docs/cluster.md`` under test."""
import json
import shutil
import time
from collections import deque
from pathlib import Path

import numpy as np
import pytest

from repro.core import (Provenance, builtin_pipelines, query_available_work,
                        synthesize_dataset)
from repro.core.workflow import load_unit_inputs
from repro.dist import ClusterRunner, DigestSummary, InputCache, WorkQueue
from repro.dist.cache import SUMMARY_WIRE_VERSION


@pytest.fixture()
def dataset(tmp_path):
    return synthesize_dataset(tmp_path / "ds", "locds", n_subjects=8,
                              sessions_per_subject=2, shape=(10, 10, 10))


def _work(dataset):
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(dataset, pipe)
    return pipe, units


def _summary_for(units):
    """A node summary wire claiming exactly these units' input digests."""
    s = DigestSummary()
    for u in units:
        for d in u.input_digests.values():
            s.add(d)
    return {"v": SUMMARY_WIRE_VERSION, "full": s.to_wire()}


# ---------------------------------------------------------------------------
# DigestSummary
# ---------------------------------------------------------------------------

def test_digest_summary_membership_discard_and_len():
    s = DigestSummary()
    digs = [f"digest-{i}" for i in range(50)]
    for d in digs:
        s.add(d)
    assert len(s) == 50
    assert all(d in s for d in digs)             # never a false negative
    s.discard(digs[0])
    assert digs[0] not in s
    assert all(d in s for d in digs[1:])
    s.discard("never-added")                     # no-op, not a corruption
    assert all(d in s for d in digs[1:])


def test_digest_summary_wire_roundtrip_is_sparse_and_small():
    s = DigestSummary()
    for i in range(200):
        s.add(f"blob-{i}")
    wire = s.to_wire()
    assert len(json.dumps(wire)) < 20_000        # "a few KB", not O(blobs)
    back = DigestSummary.from_wire(wire)
    assert back is not None and len(back) == 200
    assert all(f"blob-{i}" in back for i in range(200))


def test_digest_summary_unknown_version_rejected():
    s = DigestSummary()
    wire = s.to_wire()
    wire["v"] = SUMMARY_WIRE_VERSION + 1
    assert DigestSummary.from_wire(wire) is None
    assert DigestSummary.from_wire("garbage") is None
    assert DigestSummary.from_wire({"v": SUMMARY_WIRE_VERSION}) is None


# ---------------------------------------------------------------------------
# WorkUnit data-plane shape
# ---------------------------------------------------------------------------

def test_workunit_carries_manifest_digests_and_bytes(dataset):
    pipe, units = _work(dataset)
    by_path = {r.path: r for r in dataset.images}
    for u in units:
        assert set(u.input_digests) == set(u.inputs)
        for suffix, rel in u.inputs.items():
            assert u.input_digests[suffix] == by_path[rel].sha256
            assert u.input_bytes[suffix] == by_path[rel].size_bytes
        assert u.total_input_bytes == sum(u.input_bytes.values())


def test_workunit_backward_compat_without_digest_fields(dataset):
    """Old units JSON (pre-locality) still loads and schedules — blind."""
    import dataclasses
    pipe, units = _work(dataset)
    old = dataclasses.asdict(units[0])
    del old["input_digests"], old["input_bytes"]
    from repro.core.query import WorkUnit
    u = WorkUnit(**old)
    assert u.input_digests == {} and u.total_input_bytes == 0
    q = WorkQueue([u], ["a"])
    q.put_summary("a", _summary_for(units))      # summary can't match: blind
    unit, lease = q.next_unit("a")
    assert lease.local_bytes == 0


# ---------------------------------------------------------------------------
# affinity-scored grants / fills / steals / speculation / requeues
# ---------------------------------------------------------------------------

def test_grant_prefers_warm_unit_within_scan_window(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])                  # all 16 units on one deque
    warm = units[5]                              # not the deque head
    assert q.put_summary("a", _summary_for([warm])) is True
    unit, lease = q.next_unit("a")
    assert unit.job_id == warm.job_id
    assert lease.local_bytes == warm.total_input_bytes
    # with the warm unit gone, grants degrade to FIFO order
    unit2, lease2 = q.next_unit("a")
    assert unit2.job_id == units[0].job_id and lease2.local_bytes == 0


def test_grant_without_summary_is_fifo(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    granted = [q.next_unit("a")[0].job_id for _ in range(4)]
    assert granted == [u.job_id for u in units[:4]]
    assert q.stats_snapshot()["locality"]["scored_grants"] == 0


def test_backlog_fill_takes_warmest_units_first(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units)                         # zero nodes: all backlogged
    assert q.register("w0")
    warm = [units[7], units[12], units[3]]
    q.put_summary("w0", _summary_for(warm))
    got = [q.next_unit("w0")[0].job_id for _ in range(3)]
    assert set(got) == {u.job_id for u in warm}  # top-k by affinity
    # a second, summary-less registrant fills FIFO from the remainder
    assert q.register("w1")
    unit, lease = q.next_unit("w1")
    assert unit.job_id not in got and lease.local_bytes == 0


def test_steal_takes_victim_cold_thief_warm_units(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["victim", "thief"])
    victim_units = [units[i] for i in q._queues["victim"]]
    # victim is warm for its first half, thief for the victim's second half
    q.put_summary("victim", _summary_for(victim_units[:4]))
    q.put_summary("thief", _summary_for(victim_units[4:]))
    for _ in range(len(q._queues["thief"])):     # drain thief's own deque
        q.next_unit("thief")
    unit, lease = q.next_unit("thief")           # forces the steal
    assert q.steals["thief"] == 1
    stolen_ids = {unit.job_id} | {units[i].job_id
                                  for i in q._queues["thief"]}
    cold_ids = {u.job_id for u in victim_units[4:]}
    assert stolen_ids == cold_ids                # victim kept its warm half
    assert lease.local_bytes > 0                 # and the thief got warm work
    st = q.stats_snapshot()["locality"]
    assert st["steals_scored"] == 1 and st["stolen_local_bytes"] > 0


def test_steal_tie_break_round_robins_among_equal_victims(dataset):
    """Regression (ISSUE 4 satellite): ``max()`` on ``(len, node_id)``
    tuples broke ties by node-id string order, so every steal from
    equal-depth victims hit the lexicographically-last node. Ties must
    round-robin: successive steals alternate over the tied victims."""
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["thief", "va", "vb"])
    hit = []
    for _ in range(4):
        q._queues["thief"].clear()               # force the next steal
        q._queues["va"] = deque([0, 1, 2])
        q._queues["vb"] = deque([3, 4, 5])
        q._steal_into("thief")
        va, vb = len(q._queues["va"]), len(q._queues["vb"])
        hit.append("va" if va < 3 else "vb")
    assert set(hit) == {"va", "vb"}              # both victims get hit
    assert hit[0] != hit[1]                      # strict alternation


def test_speculate_auto_places_twin_on_warmest_node(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a", "b", "c"])
    unit, lease = q.next_unit("a")
    q.mark_started(lease.unit_idx)
    q.put_summary("c", _summary_for([unit]))
    twin = q.speculate(lease.unit_idx)           # queue picks the target
    assert twin is not None and twin.node_id == "c"
    assert twin.local_bytes == unit.total_input_bytes
    # blind fallback: no summary anywhere -> a valid non-holder target
    q2 = WorkQueue(units, ["a", "b"])
    u3, l3 = q2.next_unit("a")
    q2.mark_started(l3.unit_idx)
    twin2 = q2.speculate(l3.unit_idx)
    assert twin2 is not None and twin2.node_id == "b"


def test_dead_node_orphans_requeue_to_warm_survivor(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["dying", "cold", "warm"])
    orphan_units = [units[i] for i in q._queues["dying"]]
    q.put_summary("warm", _summary_for(orphan_units))
    q.mark_dead("dying")
    warm_depth = q.queue_depths()["warm"]
    # every orphan went to the node already holding its bytes, despite it
    # being no shallower than the cold one
    assert warm_depth >= len(orphan_units) + 1


def test_summary_version_skew_fails_soft_and_is_counted(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    assert q.put_summary("a", {"v": 99, "full": {}}) is False
    assert q.put_summary("a", "garbage") is False
    assert q.put_summary("ghost", _summary_for(units)) is False   # unknown
    st = q.stats_snapshot()
    assert st["locality"]["summary_rejected"] == 2
    assert st["summary_nodes"] == []
    unit, lease = q.next_unit("a")               # still schedulable, blind
    assert unit is not None and lease.local_bytes == 0


def test_locality_disabled_ignores_summaries(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"], locality=False)
    q.put_summary("a", _summary_for([units[5]]))
    unit, lease = q.next_unit("a")
    assert unit.job_id == units[0].job_id        # FIFO, summary ignored
    assert lease.local_bytes == 0


# ---------------------------------------------------------------------------
# summary deltas + stats plumbing (cache -> heartbeat -> stats_snapshot)
# ---------------------------------------------------------------------------

def test_heartbeat_delta_tracks_cache_churn(dataset, tmp_path):
    pipe, units = _work(dataset)
    cache = InputCache(tmp_path / "c", max_bytes=1 << 30)
    load_unit_inputs(units[0], dataset.root, cache=cache)
    cursor, full = cache.summary_sync()
    q = WorkQueue(units, ["a"])
    assert q.put_summary("a", full) is True
    assert q._local_bytes(0, "a") == units[0].total_input_bytes
    # new insert travels as a heartbeat delta
    load_unit_inputs(units[1], dataset.root, cache=cache)
    cursor, delta = cache.summary_delta_since(cursor)
    assert units[1].input_digests["T1w"] in delta["add"]
    q.heartbeat("a", summary_delta=delta)
    assert q._local_bytes(1, "a") == units[1].total_input_bytes
    # the piggybacked stats surface in stats_snapshot
    st = q.stats_snapshot()
    assert st["cache"]["a"]["misses"] == 2
    assert st["cache_totals"]["bytes_from_storage"] > 0
    assert st["cache_hit_rate"] == 0.0


def test_delta_cursor_off_window_degrades_to_full_resync(tmp_path):
    from repro.dist.cache import SUMMARY_OPS_RETAINED
    cache = InputCache(tmp_path / "c", max_bytes=1 << 30)
    np.save(tmp_path / "x.npy", np.zeros(4, dtype=np.float32))
    cache.fetch_array(tmp_path / "x.npy")
    # push the op window far past a cursor of 0
    cache._seq = SUMMARY_OPS_RETAINED + 10
    cache._ops.clear()
    cache._ops.append((cache._seq, "add", "recent"))
    _, wire = cache.summary_delta_since(0)
    assert "full" in wire                        # resync, not a partial delta


def test_delta_cursor_exactly_at_window_boundary(tmp_path):
    """Off-by-one guard: a consumer whose cursor sits exactly at the oldest
    retained op's predecessor is still *inside* the window — it must get a
    delta carrying every retained op, not a full resync; one op older and
    it has genuinely fallen off. Cursors are relative to the cache's
    per-life seq base, so the test reads the base first."""
    cache = InputCache(tmp_path / "c", max_bytes=1 << 30)
    base = cache._seq
    for i in range(3):
        np.save(tmp_path / f"{i}.npy", np.full(4, i, dtype=np.float32))
        cache.fetch_array(tmp_path / f"{i}.npy")     # ops seq base+1..base+3
    cache._ops.popleft()                             # window slid: base+2..+3
    _, wire = cache.summary_delta_since(base + 1)    # boundary: still a delta
    assert "full" not in wire and len(wire["add"]) == 2
    _, wire = cache.summary_delta_since(base)        # one older: off-window
    assert "full" in wire
    # no ops retained: only a cursor exactly at the counter gets a delta
    cache._ops.clear()
    _, wire = cache.summary_delta_since(cache._seq)
    assert "full" not in wire and wire["add"] == [] and wire["drop"] == []
    _, wire = cache.summary_delta_since(cache._seq - 1)
    assert "full" in wire                            # ops lost: must resync


def test_delta_after_producer_restart_resyncs_empty_summary(dataset, tmp_path):
    """A producer that restarts with an empty cache resets its op counter;
    a consumer still holding the previous life's cursor must get a full
    (now empty) summary — a bare empty delta would leave the coordinator
    scoring against blobs that no longer exist, forever."""
    pipe, units = _work(dataset)
    cdir = tmp_path / "c"
    cache = InputCache(cdir, max_bytes=1 << 30)
    load_unit_inputs(units[0], dataset.root, cache=cache)
    cursor, full = cache.summary_sync()
    assert cursor > 0
    q = WorkQueue(units, ["a"])
    assert q.put_summary("a", full) is True
    assert q._local_bytes(0, "a") == units[0].total_input_bytes
    # crash + wipe: the node comes back with a fresh, empty cache
    shutil.rmtree(cdir)
    fresh = InputCache(cdir, max_bytes=1 << 30)
    new_cursor, wire = fresh.summary_delta_since(cursor)
    assert "full" in wire                # cross-life cursor: full resync
    q.heartbeat("a", summary_delta=wire)
    assert q._local_bytes(0, "a") == 0   # stale membership corrected
    # and the consumer's new cursor tracks the fresh life contiguously
    assert new_cursor == fresh._seq
    load_unit_inputs(units[1], dataset.root, cache=fresh)
    _, delta = fresh.summary_delta_since(new_cursor)
    assert units[1].input_digests["T1w"] in delta["add"]


def test_delta_after_restart_never_aliases_even_with_new_ops(dataset, tmp_path):
    """Regression: with a counter restarting at 0, a new life that performed
    >= cursor ops before the consumer's next request made the stale cursor
    look in-window, and the partial delta kept the previous life's phantom
    blobs in the consumer's summary forever. The per-life random seq base
    must push any cross-life cursor outside the window -> full resync."""
    pipe, units = _work(dataset)
    cdir = tmp_path / "c"
    cache = InputCache(cdir, max_bytes=1 << 30)
    load_unit_inputs(units[0], dataset.root, cache=cache)
    cursor, _ = cache.summary_sync()
    # wipe + restart, then the new life does MORE ops than the old cursor
    # ever counted before the consumer asks again
    shutil.rmtree(cdir)
    fresh = InputCache(cdir, max_bytes=1 << 30)
    for u in units[1:5]:
        load_unit_inputs(u, dataset.root, cache=fresh)
    _, wire = fresh.summary_delta_since(cursor)
    assert "full" in wire                # not a partial delta of the new life
    back = DigestSummary.from_wire(wire["full"])
    assert units[0].input_digests["T1w"] not in back     # phantom gone
    assert all(u.input_digests["T1w"] in back for u in units[1:5])


def test_eviction_travels_as_drop_delta(dataset, tmp_path):
    pipe, units = _work(dataset)
    one = (Path(dataset.root) / units[0].inputs["T1w"]).stat().st_size
    cache = InputCache(tmp_path / "c", max_bytes=int(one * 1.5))
    load_unit_inputs(units[0], dataset.root, cache=cache)
    cursor, _ = cache.summary_sync()
    load_unit_inputs(units[1], dataset.root, cache=cache)    # evicts unit 0
    _, delta = cache.summary_delta_since(cursor)
    assert units[0].input_digests["T1w"] in delta["drop"]
    assert units[1].input_digests["T1w"] in delta["add"]
    assert units[0].input_digests["T1w"] not in cache.summary


def test_renew_piggybacks_summary_delta(dataset):
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a", "b"])
    unit, lease = q.next_unit("a")
    delta = {"v": SUMMARY_WIRE_VERSION,
             "add": list(unit.input_digests.values()), "drop": []}
    assert q.renew(lease.unit_idx, "a", lease.epoch, summary_delta=delta)
    assert q._local_bytes(lease.unit_idx, "a") == unit.total_input_bytes


def test_cache_stats_track_bytes_moved(dataset, tmp_path):
    pipe, units = _work(dataset)
    cache = InputCache(tmp_path / "c", max_bytes=1 << 30)
    load_unit_inputs(units[0], dataset.root, cache=cache)
    load_unit_inputs(units[0], dataset.root, cache=cache)
    st = cache.stats()
    size = (Path(dataset.root) / units[0].inputs["T1w"]).stat().st_size
    assert st["bytes_from_storage"] == size      # one miss
    assert st["bytes_from_cache"] == size        # one hit
    _, _, _, hit_bytes, *_ = load_unit_inputs(units[0], dataset.root,
                                             cache=cache)
    assert hit_bytes == size


# ---------------------------------------------------------------------------
# rpc transport: summaries over the wire + downgrade fail-soft
# ---------------------------------------------------------------------------

def test_put_summary_and_scored_grant_over_rpc(dataset):
    from repro.dist import QueueClient, QueueServer
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        assert c.put_summary("a", _summary_for([units[5]])) is True
        unit, lease = c.next_unit("a")
        assert unit.job_id == units[5].job_id
        assert lease.local_bytes == units[5].total_input_bytes
        assert c.stats_snapshot()["locality"]["scored_grants"] == 1
        c.close()


def test_client_downgrades_against_pre_summary_server(dataset, monkeypatch):
    """Version skew: a coordinator without locality support rejects the new
    params; the client downgrades to the blind protocol instead of dying."""
    from repro.dist import QueueClient, QueueServer
    from repro.dist import rpc as rpc_mod
    pipe, units = _work(dataset)
    monkeypatch.setattr(rpc_mod, "_METHODS",
                        rpc_mod._METHODS - {"put_summary"})
    q = WorkQueue(units, ["a"])
    with QueueServer(q) as srv:
        c = QueueClient(srv.address)
        assert c.put_summary("a", _summary_for(units)) is False
        assert c._summaries_ok is False
        # later piggybacks silently drop the summary payload
        c.heartbeat("a", summary_delta={"v": 1, "add": [], "drop": []})
        assert c.register("w", summary=_summary_for(units)) is True
        assert c.next_unit("a") is not None      # scheduling unaffected
        c.close()


# ---------------------------------------------------------------------------
# InputCache._compact_index crash-mid-compaction recovery (ISSUE satellite)
# ---------------------------------------------------------------------------

def test_compact_index_crash_recovers_consistent_state(dataset, tmp_path):
    """A crash mid-compaction can leave a torn index.jsonl tail and an
    orphaned atomic-write tmp. A restarted cache must come up consistent —
    torn lines skipped, every served hit still digest-verified — possibly
    smaller, never corrupt."""
    pipe, units = _work(dataset)
    cdir = tmp_path / "c"
    cache = InputCache(cdir, max_bytes=1 << 30)
    for u in units[:4]:
        load_unit_inputs(u, dataset.root, cache=cache)
    index = cdir / "index.jsonl"
    lines = index.read_text().splitlines(keepends=True)
    assert len(lines) == 4
    # crash mid-rewrite: half of the last line, plus a leftover dot-tmp from
    # the interrupted atomic_write_bytes
    index.write_text("".join(lines[:2]) + lines[2][:len(lines[2]) // 2])
    (cdir / ".index.jsonl.tmp-dead").write_bytes(b'{"k": "torn')
    (cache.blob_dir / ".blob.tmp-dead").write_bytes(b"torn blob bytes")
    c2 = InputCache(cdir, max_bytes=1 << 30)
    # intact entries hit; the torn one degrades to a (correct) miss
    assert load_unit_inputs(units[0], dataset.root, cache=c2)[2] is True
    assert load_unit_inputs(units[1], dataset.root, cache=c2)[2] is True
    loaded = load_unit_inputs(units[2], dataset.root, cache=c2)
    assert loaded[2] is False
    # and the re-fetched digest matches a fresh from-storage read
    ref = load_unit_inputs(units[2], dataset.root)
    assert loaded[1] == ref[1]
    # summary reflects exactly the adopted blobs (all four survived on disk)
    assert all(d in c2.summary for u in units[:4]
               for d in u.input_digests.values())
    # the cache keeps working: inserts, eviction-triggered compaction included
    for u in units:
        load_unit_inputs(u, dataset.root, cache=c2)
    assert load_unit_inputs(units[-1], dataset.root, cache=c2)[2] is True


def test_compact_index_crash_mid_eviction_keeps_blob_truth(dataset, tmp_path):
    """Compaction interrupted *between* in-memory eviction and the index
    rewrite: the stale index may reference evicted blobs, but a restarted
    cache only adopts entries whose blob file still exists — hits stay
    verified, state shrinks instead of corrupting."""
    pipe, units = _work(dataset)
    cdir = tmp_path / "c"
    cache = InputCache(cdir, max_bytes=1 << 30)
    for u in units[:3]:
        load_unit_inputs(u, dataset.root, cache=cache)
    # simulate: eviction unlinked a blob but crashed before compaction
    victim_digest = units[0].input_digests["T1w"]
    (cache.blob_dir / victim_digest).unlink()
    c2 = InputCache(cdir, max_bytes=1 << 30)
    assert victim_digest not in c2.summary       # gone blob, gone summary bit
    assert load_unit_inputs(units[0], dataset.root, cache=c2)[2] is False
    assert load_unit_inputs(units[1], dataset.root, cache=c2)[2] is True
    assert victim_digest in c2.summary           # the miss re-inserted it


# ---------------------------------------------------------------------------
# end-to-end: warm per-node caches turn into placement + provenance stamps
# ---------------------------------------------------------------------------

def test_cluster_locality_end_to_end_stamps_provenance(dataset, tmp_path):
    pipe, units = _work(dataset)
    kw = dict(nodes=3, poll_s=0.02, cache_dir=tmp_path / "hosts",
              cache_per_node=True, straggler_factor=100.0)
    warm = ClusterRunner(pipe, dataset.root, **kw)
    results = warm.run(units)
    assert sum(r.status == "ok" for r in results) == len(units)
    shutil.rmtree(Path(dataset.root) / "derivatives")
    units2, _ = query_available_work(dataset, pipe)
    runner = ClusterRunner(pipe, dataset.root, partition="backlog", **kw)
    results2 = runner.run(units2)
    assert sum(r.status == "ok" for r in results2) == len(units2)
    provs = [Provenance.load(Path(u.out_dir)) for u in units2]
    hits = [p for p in provs if p.cache_hit]
    assert hits, "warm per-node caches produced no cache-hit commits"
    # the scheduler predicted locality for the hits it engineered
    scored = [p for p in provs if p.locality_score > 0.0]
    assert scored, "no grant was scored against a digest summary"
    assert any(p.bytes_from_cache > 0 for p in provs)
    assert runner.stats.locality["scored_grants"] > 0
    assert runner.stats.cache_by_node is not None
    total_hits = sum(st["hits"] for st in runner.stats.cache_by_node.values())
    assert total_hits >= len(hits)
    # results_snapshot meta carries the same stamps for remote folding
    snap = runner.queue.results_snapshot()
    assert any(m.get("bytes_from_cache", 0) > 0
               for m in snap["primaries"].values())


def test_provenance_roundtrips_locality_stamps(tmp_path):
    from repro.core.provenance import make_provenance
    p = make_provenance("pipe", "digest", {}, {}, time.time(),
                        locality_score=0.75, bytes_from_cache=4096)
    p.save(tmp_path)
    back = Provenance.load(tmp_path)
    assert back.locality_score == 0.75 and back.bytes_from_cache == 4096


# ---------------------------------------------------------------------------
# warm-set index: the incremental scorer behind every placement decision
# ---------------------------------------------------------------------------

def _flat_units(n, *, unique_digests=False):
    """Synthetic units with one input each; digests shared pairwise unless
    ``unique_digests`` (so both the overlap and the distinct cases exist)."""
    from repro.core.query import WorkUnit
    pool = n if unique_digests else max(1, n // 2)
    return [WorkUnit(dataset="wd", subject=f"s{i:05d}", session="01",
                     pipeline="p", pipeline_digest="pd",
                     inputs={"T1w": f"in/{i}.nii"}, out_dir=f"out/{i}",
                     input_digests={"T1w": f"dig-{i % pool}"},
                     input_bytes={"T1w": 1000 + (i % 7) * 10})
            for i in range(n)]


def test_warm_index_full_push_matches_bloom_scorer_probe_for_probe():
    from repro.dist.placement import WarmSetIndex, unit_local_bytes
    units = _flat_units(40)
    idx = WarmSetIndex(units)
    s = DigestSummary()
    for u in units[10:20]:
        for d in u.input_digests.values():
            s.add(d)
    idx.rebuild("n", s)                         # Bloom probes, no exact list
    for i, u in enumerate(units):
        assert idx.score("n", i) == unit_local_bytes(u, s)


def test_warm_index_exact_digest_list_beats_bloom_false_positives():
    from repro.dist.placement import WarmSetIndex
    units = _flat_units(30, unique_digests=True)
    idx = WarmSetIndex(units)
    held = sorted(units[3].input_digests.values())
    # a deliberately saturated filter claims everything; the exact list wins
    s = DigestSummary(m=1, k=1)
    for d in held:
        s.add(d)
    idx.rebuild("n", s, digests=held)
    assert idx.score("n", 3) == units[3].total_input_bytes
    assert idx.score("n", 4) == 0               # Bloom alone would say warm


def test_warm_index_delta_matches_fresh_rebuild():
    from repro.dist.placement import WarmSetIndex
    units = _flat_units(24, unique_digests=True)
    a = WarmSetIndex(units)
    a.rebuild("n", set(), digests=[])
    final = set()
    for i in (1, 5, 9, 5):                      # 5 added twice: a multiset
        a.add("n", f"dig-{i}")
        final.add(f"dig-{i}")
    a.discard("n", "dig-9")
    final.discard("dig-9")
    a.discard("n", "dig-5")                     # one copy left: still warm
    b = WarmSetIndex(units)
    b.rebuild("n", final, digests=sorted(final))
    assert a.scores("n") == b.scores("n")
    a.discard("n", "dig-5")                     # last copy: cold now
    assert a.score("n", 5) == 0


def test_warm_index_ignores_unreferenced_digests_and_drops_nodes():
    from repro.dist.placement import WarmSetIndex
    units = _flat_units(8, unique_digests=True)
    idx = WarmSetIndex(units)
    idx.add("n", "dig-2")
    idx.add("n", "never-referenced-anywhere")   # one dict miss, no state
    assert idx.scores("n") == {2: units[2].total_input_bytes}
    idx.drop_node("n")
    assert idx.scores("n") == {}
    idx.discard("ghost", "dig-2")               # unknown node: no-op


def test_warm_index_best_node_matches_placement_best_node():
    from repro.dist.placement import WarmSetIndex, best_node
    units = _flat_units(20)
    idx = WarmSetIndex(units)
    summaries = {"a": {u for x in units[:6]
                       for u in x.input_digests.values()},
                 "b": {u for x in units[6:14]
                       for u in x.input_digests.values()},
                 "c": set()}
    for n, held in summaries.items():
        idx.rebuild(n, held, digests=sorted(held))
    load = {"a": 3, "b": 1, "c": 0}
    for i, u in enumerate(units):
        assert (idx.best_node(i, ["a", "b", "c"], load)
                == best_node(u, ["a", "b", "c"], summaries, load))


# ---------------------------------------------------------------------------
# the 512-unit cap is gone: scored placement on either side of the old edge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [511, 512, 513])
def test_backlog_fill_stays_scored_across_old_cap_boundary(n):
    """The old coordinator went placement-blind past a 512-entry backlog
    (LOCALITY_BULK_SCAN_CAP): a node whose cache held the *last* admitted
    unit was handed the FIFO head instead. The index-backed fill must grant
    the warm unit first at 511, 512 and 513 alike."""
    units = _flat_units(n, unique_digests=True)
    q = WorkQueue(units)                        # zero nodes: all backlogged
    assert q.register("w")
    warm = units[-1]                            # admitted last: FIFO-worst
    q.put_summary("w", _summary_for([warm]))
    unit, lease = q.next_unit("w")
    assert unit.job_id == warm.job_id
    assert lease.local_bytes == warm.total_input_bytes
    st = q.stats_snapshot()["locality"]
    assert st["scored_grants"] == 1 and st["blind_grants"] == 0


@pytest.mark.parametrize("n", [511, 515])
def test_steal_stays_scored_across_old_cap_boundary(n):
    """Same edge for stealing: past 512 entries the old steal took the blind
    tail half, so a thief-warm unit parked in the victim's front half was
    unstealable. It must be stolen at any depth now."""
    units = _flat_units(n, unique_digests=True)
    q = WorkQueue(units)
    assert q.register("victim")
    q.next_unit("victim")                       # fill victim's deque (> cap)
    assert q.register("thief")
    warm_idx = q._queues["victim"][len(q._queues["victim"]) // 4]
    q.put_summary("thief", _summary_for([units[warm_idx]]))
    unit, lease = q.next_unit("thief")          # backlog empty: steals
    assert q.steals["thief"] == 1
    assert q.stats_snapshot()["locality"]["steals_scored"] == 1
    got = {lease.unit_idx} | set(q._queues["thief"])
    assert warm_idx in got                      # front-half warm unit stolen
    assert unit.job_id == units[warm_idx].job_id  # and granted first


def test_queue_local_bytes_agrees_with_shared_scorer(dataset):
    """The index is a cache of the shared placement scorer, not a second
    scorer: after any summary push, the queue-side score for every unit
    equals a fresh unit_local_bytes() probe of the stored summary."""
    from repro.dist.placement import unit_local_bytes
    pipe, units = _work(dataset)
    q = WorkQueue(units, ["a", "b"])
    q.put_summary("a", _summary_for(units[:5]))
    q.put_summary("b", _summary_for(units[5:9]))
    q.heartbeat("a", summary_delta={
        "v": 1, "add": list(units[9].input_digests.values()), "drop": []})
    for node in ("a", "b"):
        for i, u in enumerate(units):
            assert (q._warm.score(node, i)
                    == unit_local_bytes(u, q._summaries.get(node)))


def test_summary_sync_wire_carries_exact_digest_list(dataset, tmp_path):
    pipe, units = _work(dataset)
    cache = InputCache(tmp_path / "cache", max_bytes=1 << 30)
    load_unit_inputs(units[0], dataset.root, cache=cache)
    _cursor, wire = cache.summary_sync()
    assert sorted(wire["digests"]) == wire["digests"]
    assert set(units[0].input_digests.values()) <= set(wire["digests"])
    # a queue fed that wire scores exactly, not probabilistically
    q = WorkQueue(units, ["a"])
    assert q.put_summary("a", wire) is True
    assert q._warm.score("a", 0) == units[0].total_input_bytes
