"""Incremental decode must reproduce teacher-forced prefill logits — the
KV/SSM cache correctness test across every architecture family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params, forward_prefill, forward_decode

ARCHS = ["llama3.2-1b", "glm4-9b", "granite-34b", "h2o-danube-1.8b",
         "rwkv6-1.6b", "zamba2-1.2b", "whisper-small", "internvl2-76b",
         "moonshot-v1-16b-a3b", "llama4-scout-17b-a16e"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:   # remove capacity drops so paths agree exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=float(cfg.moe.n_experts)))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.encoder is not None:
        batch["enc_embeds"] = jax.random.normal(key, (B, cfg.encoder.enc_seq,
                                                      cfg.d_model))
    if cfg.vlm is not None:
        batch["embeds"] = jax.random.normal(key, (B, cfg.vlm.n_patches, cfg.d_model))
    full_logits, _ = forward_prefill(cfg, params, batch, compute_dtype=jnp.float32)

    b2 = dict(batch)
    b2["tokens"] = toks[:, :S - 1]
    _, cache = forward_prefill(cfg, params, b2, compute_dtype=jnp.float32)

    def pad_seq(c):
        pw = [(0, 0)] * c.ndim
        pw[-3] = (0, 1)
        return jnp.pad(c, pw)
    if "k" in cache and cache["k"].ndim >= 4:
        cache = {k: (pad_seq(v) if k in ("k", "v") else v) for k, v in cache.items()}
    pos = S - 1 + (cfg.vlm.n_patches if cfg.vlm is not None else 0)
    step_logits, _ = forward_decode(cfg, params, cache, toks[:, S - 1:S],
                                    jnp.int32(pos), compute_dtype=jnp.float32)
    err = np.max(np.abs(np.asarray(full_logits) - np.asarray(step_logits[:, 0])))
    assert err < 2e-3, f"{arch}: {err}"


def test_swa_ring_buffer_decode():
    """SWA decode with a window-sized ring cache matches a full cache."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    cfg = dataclasses.replace(cfg, sliding_window=16)
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, S = 1, 40
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    # teacher-forced reference over S+1 tokens
    ref_logits, _ = forward_prefill(cfg, params, {"tokens": toks},
                                    compute_dtype=jnp.float32)
    # incremental with ring cache (Smax = window)
    from repro.models import init_cache
    cache = init_cache(cfg, B, S + 1, jnp.float32)
    assert cache["k"].shape[2] == 16     # ring of window size
    logits = None
    for t in range(S + 1):
        logits, cache = forward_decode(cfg, params, cache, toks[:, t:t + 1],
                                       jnp.int32(t), compute_dtype=jnp.float32)
    err = np.max(np.abs(np.asarray(ref_logits) - np.asarray(logits[:, 0])))
    assert err < 2e-3, err
