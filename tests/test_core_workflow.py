"""End-to-end paper workflow: synthesize BIDS dataset -> manifest -> query ->
job generation -> execution -> provenance -> idempotent re-query. Plus fault
injection (retry), straggler duplication, and the exclusion CSV."""
import csv
import json
import re
from pathlib import Path

import numpy as np
import pytest

from repro.core import (DatasetManifest, IntegrityError, LocalRunner,
                        builtin_pipelines, generate_jobs, is_complete,
                        load_units, query_available_work, resource_status,
                        run_unit, synthesize_dataset)


@pytest.fixture()
def dataset(tmp_path):
    return synthesize_dataset(tmp_path, "testds", n_subjects=3,
                              sessions_per_subject=2, shape=(12, 12, 12))


def test_manifest_scan_and_validate(dataset):
    assert len(dataset.images) > 0
    assert dataset.validate() == []
    sessions = dataset.sessions()
    assert len(sessions) == 6
    # round-trip persistence
    p = Path(dataset.root) / "manifest.json"
    dataset.save(p)
    loaded = DatasetManifest.load(p)
    assert len(loaded.images) == len(dataset.images)
    assert loaded.images[0].sha256 == dataset.images[0].sha256


def test_query_and_exclusions(dataset, tmp_path):
    pipe = builtin_pipelines()["dwi_prequal"]      # needs T1w + dwi
    work, excluded = query_available_work(dataset, pipe)
    # odd-numbered subjects have no DWI (synthesized that way)
    assert len(work) > 0 and len(excluded) > 0
    assert all("missing input" in e.reason for e in excluded)


def test_full_processing_loop(dataset, tmp_path):
    pipe = builtin_pipelines()["bias_correct"]
    plan = generate_jobs(dataset, pipe, tmp_path / "jobs")
    assert plan.slurm_script and Path(plan.slurm_script).exists()
    slurm = Path(plan.slurm_script).read_text()
    assert "#SBATCH --array=0-" in slurm
    assert Path(plan.exclusion_csv).exists()
    assert len(plan.units) == 6

    runner = LocalRunner(pipe, dataset.root)
    results = runner.run(plan.units)
    assert all(r.status == "ok" for r in results)
    # outputs + provenance exist
    for u in plan.units:
        assert is_complete(Path(u.out_dir), pipe.digest())
        prov = json.loads((Path(u.out_dir) / "provenance.json").read_text())
        assert prov["status"] == "ok" and prov["inputs"]

    # idempotency: re-query finds nothing to do
    work2, excluded2 = query_available_work(dataset, pipe)
    assert work2 == []
    assert all("already processed" in e.reason for e in excluded2)


def test_digest_change_triggers_reprocessing(dataset, tmp_path):
    pipes = builtin_pipelines()
    pipe = pipes["bias_correct"]
    plan = generate_jobs(dataset, pipe, tmp_path / "jobs")
    LocalRunner(pipe, dataset.root).run(plan.units)
    # same pipeline, new version -> different digest -> everything re-queues
    import dataclasses
    pipe2 = type(pipe)(dataclasses.replace(pipe.spec, version="2.0"), pipe.fn)
    work, _ = query_available_work(dataset, pipe2)
    assert len(work) == 6


def test_generate_jobs_writes_manifest_and_every_referenced_path(dataset, tmp_path):
    """Regression: the SLURM template interpolated ``{out_dir}/manifest.json``
    (and a logs dir for ``#SBATCH --output``) that generate_jobs never
    created — an array submitted from the generated script referenced paths
    that did not exist. Every absolute path the script names must exist at
    submit time."""
    pipe = builtin_pipelines()["bias_correct"]
    plan = generate_jobs(dataset, pipe, tmp_path / "jobs")
    assert plan.manifest_file and Path(plan.manifest_file).exists()
    # the manifest next to the script reloads to the scanned dataset
    loaded = DatasetManifest.load(plan.manifest_file)
    assert len(loaded.images) == len(dataset.images)
    assert loaded.images[0].sha256 == dataset.images[0].sha256
    script = Path(plan.slurm_script).read_text()
    assert str(plan.manifest_file) in script
    referenced = re.findall(r"(/[^\s\\$]+)", script)
    assert referenced, "no paths found in the generated script?"
    for raw in referenced:
        # SLURM patterns (%x_%a.out) resolve at runtime; their dir must exist
        target = Path(raw.split("%")[0].rstrip("/"))
        assert target.exists(), f"script references missing {target}"


def test_units_json_roundtrip_reconstructs_identical_units(dataset, tmp_path):
    """The units JSON is the hand-off artifact to SLURM array tasks and
    ``repro.dist.rpc serve``: reloading it must reconstruct WorkUnits equal
    to the originals *including* the data-plane fields (input_digests /
    input_bytes) — silently dropping those would leave every downstream
    queue locality-blind."""
    pipe = builtin_pipelines()["bias_correct"]
    plan = generate_jobs(dataset, pipe, tmp_path / "jobs")
    reloaded = load_units(plan.units_file)
    assert reloaded == plan.units                # dataclass eq: every field
    for orig, back in zip(plan.units, reloaded):
        assert back.input_digests == orig.input_digests != {}
        assert back.input_bytes == orig.input_bytes != {}
        assert back.total_input_bytes == orig.total_input_bytes > 0
    # and a second round-trip is byte-stable
    from repro.core import dump_units
    again = tmp_path / "again.json"
    dump_units(reloaded, again)
    assert again.read_text() == Path(plan.units_file).read_text()


def test_retry_on_injected_failure(dataset):
    pipe = builtin_pipelines()["bias_correct"]
    work, _ = query_available_work(dataset, pipe)
    fails = {"n": 0}

    def flaky(unit, attempt):
        if attempt == 1:          # every unit fails once, succeeds on retry
            fails["n"] += 1
            raise RuntimeError("injected node failure")

    runner = LocalRunner(pipe, dataset.root, max_retries=2, fault_hook=flaky)
    results = runner.run(work)
    ok = [r for r in results if r.status == "ok"]
    assert len(ok) == len(work)
    assert fails["n"] == len(work)
    assert all(r.attempts == 2 for r in ok)


def test_failed_unit_records_failed_provenance(dataset):
    pipe = builtin_pipelines()["bias_correct"]
    work, _ = query_available_work(dataset, pipe)

    def always_fail(unit, attempt):
        raise RuntimeError("dead node")

    res = run_unit(work[0], pipe, dataset.root, fault_hook=always_fail)
    assert res.status == "failed"
    assert not is_complete(Path(work[0].out_dir), pipe.digest())
    # and the work unit is still queryable (not lost)
    work2, _ = query_available_work(dataset, pipe)
    assert any(u.job_id == work[0].job_id for u in work2)


def test_resource_status(tmp_path):
    st = resource_status(tmp_path)
    assert st["disk_free_gb"] > 0
    assert st["disk_total_gb"] >= st["disk_free_gb"]


def test_pipeline_outputs_sensible(dataset):
    pipes = builtin_pipelines()
    t1 = np.load(Path(dataset.root) / dataset.images[0].path)
    out = pipes["bias_correct"].run({"T1w": t1})
    assert out["T1w_biascorr"].shape == t1.shape
    assert np.all(np.isfinite(out["T1w_biascorr"]))
    # bias correction should reduce the coefficient of variation
    cv = lambda a: a.std() / a.mean()
    assert cv(out["T1w_biascorr"]) < cv(t1) * 1.05
    seg = pipes["segment_unest"].run({"T1w": t1})
    assert seg["segmentation"].shape == t1.shape
    assert set(np.unique(seg["segmentation"])) <= {0, 1, 2, 3}
