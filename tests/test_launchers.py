"""Launcher-level behaviour: training driver, serving driver, SLURM writers."""
from pathlib import Path

import numpy as np

from repro.configs import get_config
from repro.launch.serve import serve_batch
from repro.launch.slurm import write_pod_launch
from repro.launch.train import train


def test_train_driver_runs_and_checkpoints(tmp_path):
    params, losses = train("llama3.2-1b", steps=6, batch=2, seq=32,
                           data_dir=str(tmp_path / "d"),
                           ckpt_dir=str(tmp_path / "c"), ckpt_every=3,
                           log_every=3)
    assert len(losses) == 6
    assert all(np.isfinite(losses))
    assert list((tmp_path / "c").glob("step_*"))


def test_train_driver_resume(tmp_path):
    train("llama3.2-1b", steps=4, batch=2, seq=32,
          data_dir=str(tmp_path / "d"), ckpt_dir=str(tmp_path / "c"),
          ckpt_every=2)
    _, losses = train("llama3.2-1b", steps=6, batch=2, seq=32,
                      data_dir=str(tmp_path / "d"), ckpt_dir=str(tmp_path / "c"),
                      ckpt_every=2, resume=True)
    assert len(losses) == 2          # resumed at step 4


def test_serve_batch_shapes():
    cfg = get_config("llama3.2-1b").reduced()
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16), dtype=np.int32)
    toks = serve_batch("llama3.2-1b", prompts, max_new=4)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_pod_slurm_writer(tmp_path):
    p = write_pod_launch(tmp_path, arch="glm4-9b", n_hosts=64)
    s = Path(p).read_text()
    assert "#SBATCH --array=0-63" in s
    assert "JAX_NUM_PROCESSES=64" in s
    assert "--arch glm4-9b" in s and "--resume" in s


def test_dryrun_cli_reduced_smoke(tmp_path):
    """run_cell machinery on a tiny config via the library API (no 512-dev
    env needed: use the local mesh)."""
    import jax
    from repro.configs import SHAPE_BY_NAME
    from repro.dist.sharding import Rules
    from repro.launch.dryrun import rules_kind
    shape = SHAPE_BY_NAME["train_4k"]
    assert rules_kind(shape) == "train"
    assert rules_kind(SHAPE_BY_NAME["long_500k"]) == "long"
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = Rules(mesh, "train", "fsdp", global_batch=256)
    assert r.map["batch"]  # divisible on the 1x1 mesh
