"""Launcher-level behaviour: training driver, serving driver, SLURM writers,
and the allocator/XLA environment profile the launch scripts apply."""
import shlex
from pathlib import Path

import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.env import (ENV_PROFILE_ENV, apply_env_profile, env_profile,
                              format_exports)
from repro.launch.serve import serve_batch
from repro.launch.slurm import write_pod_launch, write_shard_script
from repro.launch.train import train


def test_train_driver_runs_and_checkpoints(tmp_path):
    params, losses = train("llama3.2-1b", steps=6, batch=2, seq=32,
                           data_dir=str(tmp_path / "d"),
                           ckpt_dir=str(tmp_path / "c"), ckpt_every=3,
                           log_every=3)
    assert len(losses) == 6
    assert all(np.isfinite(losses))
    assert list((tmp_path / "c").glob("step_*"))


def test_train_driver_resume(tmp_path):
    train("llama3.2-1b", steps=4, batch=2, seq=32,
          data_dir=str(tmp_path / "d"), ckpt_dir=str(tmp_path / "c"),
          ckpt_every=2)
    _, losses = train("llama3.2-1b", steps=6, batch=2, seq=32,
                      data_dir=str(tmp_path / "d"), ckpt_dir=str(tmp_path / "c"),
                      ckpt_every=2, resume=True)
    assert len(losses) == 2          # resumed at step 4


def test_serve_batch_shapes():
    cfg = get_config("llama3.2-1b").reduced()
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16), dtype=np.int32)
    toks = serve_batch("llama3.2-1b", prompts, max_new=4)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_pod_slurm_writer(tmp_path):
    p = write_pod_launch(tmp_path, arch="glm4-9b", n_hosts=64)
    s = Path(p).read_text()
    assert "#SBATCH --array=0-63" in s
    assert "JAX_NUM_PROCESSES=64" in s
    assert "--arch glm4-9b" in s and "--resume" in s


def test_dryrun_cli_reduced_smoke(tmp_path):
    """run_cell machinery on a tiny config via the library API (no 512-dev
    env needed: use the local mesh)."""
    import jax
    from repro.configs import SHAPE_BY_NAME
    from repro.dist.sharding import Rules
    from repro.launch.dryrun import rules_kind
    shape = SHAPE_BY_NAME["train_4k"]
    assert rules_kind(shape) == "train"
    assert rules_kind(SHAPE_BY_NAME["long_500k"]) == "long"
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = Rules(mesh, "train", "fsdp", global_batch=256)
    assert r.map["batch"]  # divisible on the 1x1 mesh


# ---------------------------------------------------------------------------
# environment profile (repro.launch.env)
# ---------------------------------------------------------------------------

def test_env_profile_sets_hygiene_and_merges_xla_flags():
    prof = env_profile("worker", base={})
    assert prof["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" in prof
    assert "--xla_force_host_platform_device_count=1" in prof["XLA_FLAGS"]


def test_env_profile_never_clobbers_operator_settings():
    base = {"TF_CPP_MIN_LOG_LEVEL": "0",
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8 "
                         "--xla_dump_to=/tmp/x",
            "LD_PRELOAD": "/opt/custom.so"}
    prof = env_profile("coordinator", base=base)
    # operator-pinned vars stay out of the profile entirely; the XLA flag
    # the operator set by name wins, so XLA_FLAGS needs no merge at all
    assert "TF_CPP_MIN_LOG_LEVEL" not in prof
    assert "LD_PRELOAD" not in prof
    assert "XLA_FLAGS" not in prof


def test_env_profile_merges_only_missing_xla_flags():
    base = {"XLA_FLAGS": "--xla_dump_to=/tmp/x"}
    prof = env_profile("worker", base=base)
    assert prof["XLA_FLAGS"].startswith("--xla_dump_to=/tmp/x")
    assert "--xla_force_host_platform_device_count=1" in prof["XLA_FLAGS"]


def test_env_profile_unknown_role_rejected():
    with pytest.raises(ValueError, match="unknown role"):
        env_profile("gpu-wrangler")


def test_apply_env_profile_respects_off_switch(monkeypatch):
    monkeypatch.setenv(ENV_PROFILE_ENV, "off")
    assert apply_env_profile("worker") == {}
    assert format_exports("worker") == ""


def test_apply_env_profile_updates_environ(monkeypatch):
    monkeypatch.delenv(ENV_PROFILE_ENV, raising=False)
    monkeypatch.delenv("TF_CPP_MIN_LOG_LEVEL", raising=False)
    import os
    applied = apply_env_profile("worker")
    assert applied["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert os.environ["TF_CPP_MIN_LOG_LEVEL"] == "4"


def test_format_exports_emits_quoted_shell_lines():
    out = format_exports("worker", base={})
    lines = out.splitlines()
    assert all(line.startswith("export ") for line in lines)
    for line in lines:
        k, _, v = line[len("export "):].partition("=")
        assert shlex.split(v) == [shlex.split(v)[0]]   # one quoted value


def test_shard_script_evals_env_profile_before_python(tmp_path):
    p = write_shard_script(tmp_path, name="shard-000", n_units=4,
                           units_json="units.json",
                           manifest_json="manifest.json", data_root="/data")
    s = Path(p).read_text()
    assert 'eval "$(python -m repro.launch.env --role worker' in s
    # fail-soft on hosts where the package is missing, and the profile line
    # lands before the worker python starts
    assert "|| true" in s
    assert s.index("repro.launch.env") < s.index("repro.core.workflow")
