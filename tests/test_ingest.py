"""Paper §2.1 ingestion: convert + filter + QA + BIDS organize."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.ingest import (IngestRule, ingest_directory, write_raw_dump)
from repro.core import builtin_pipelines, query_available_work


@pytest.fixture()
def raw_dir(tmp_path):
    rng = np.random.default_rng(0)
    d = tmp_path / "raw"
    good = rng.normal(100, 20, (16, 16, 16)).astype(np.float32)
    write_raw_dump(d / "a.npz", good, subject="001", session="01", protocol="T1w")
    write_raw_dump(d / "b.npz", good + 1, subject="001", session="02",
                   protocol="T1w")
    # filtered: wrong protocol
    write_raw_dump(d / "c.npz", good, subject="002", session="01", protocol="bold")
    # filtered: resolution out of bounds
    write_raw_dump(d / "d.npz", good, subject="002", session="02",
                   protocol="T1w", resolution_mm=5.0)
    # fails QA: NaNs
    bad = good.copy(); bad[0, 0, 0] = np.nan
    write_raw_dump(d / "e.npz", bad, subject="003", session="01", protocol="T1w")
    # corrupted file
    (d / "f.npz").write_bytes(b"not a dump")
    return d


def test_ingest_counts_and_bids(raw_dir, tmp_path):
    manifest, records = ingest_directory(raw_dir, tmp_path / "bids", "study")
    by = {r.source: r for r in records}
    assert by["a.npz"].status == "ok" and by["b.npz"].status == "ok"
    assert by["c.npz"].status == "filtered"
    assert by["d.npz"].status == "filtered"
    assert by["e.npz"].status == "failed_qa"
    assert by["f.npz"].status == "corrupted"
    # BIDS-valid and manifest sees exactly the 2 accepted scans
    assert manifest.validate() == []
    assert len(manifest.images) == 2
    report = json.loads((tmp_path / "bids" / "study" /
                         "ingestion_report.json").read_text())
    assert report["counts"] == {"ok": 2, "corrupted": 1, "filtered": 2,
                                "failed_qa": 1}
    # sidecars exist next to volumes (dcm2niix behaviour)
    vol = Path(by["a.npz"].dest)
    assert vol.with_suffix(".json").exists()


def test_ingested_dataset_flows_into_workflow(raw_dir, tmp_path):
    """The §2.1 output is directly queryable by the §2.3 engine."""
    manifest, _ = ingest_directory(raw_dir, tmp_path / "bids", "study")
    pipe = builtin_pipelines()["bias_correct"]
    work, excluded = query_available_work(manifest, pipe)
    assert len(work) == 2
