"""Paper §2.1 ingestion: convert + filter + QA + BIDS organize."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.ingest import (IngestRule, ingest_directory, write_raw_dump)
from repro.core import builtin_pipelines, query_available_work


@pytest.fixture()
def raw_dir(tmp_path):
    rng = np.random.default_rng(0)
    d = tmp_path / "raw"
    good = rng.normal(100, 20, (16, 16, 16)).astype(np.float32)
    write_raw_dump(d / "a.npz", good, subject="001", session="01", protocol="T1w")
    write_raw_dump(d / "b.npz", good + 1, subject="001", session="02",
                   protocol="T1w")
    # filtered: wrong protocol
    write_raw_dump(d / "c.npz", good, subject="002", session="01", protocol="bold")
    # filtered: resolution out of bounds
    write_raw_dump(d / "d.npz", good, subject="002", session="02",
                   protocol="T1w", resolution_mm=5.0)
    # fails QA: NaNs
    bad = good.copy(); bad[0, 0, 0] = np.nan
    write_raw_dump(d / "e.npz", bad, subject="003", session="01", protocol="T1w")
    # corrupted file
    (d / "f.npz").write_bytes(b"not a dump")
    return d


def test_ingest_counts_and_bids(raw_dir, tmp_path):
    manifest, records = ingest_directory(raw_dir, tmp_path / "bids", "study")
    by = {r.source: r for r in records}
    assert by["a.npz"].status == "ok" and by["b.npz"].status == "ok"
    assert by["c.npz"].status == "filtered"
    assert by["d.npz"].status == "filtered"
    assert by["e.npz"].status == "failed_qa"
    assert by["f.npz"].status == "corrupted"
    # BIDS-valid and manifest sees exactly the 2 accepted scans
    assert manifest.validate() == []
    assert len(manifest.images) == 2
    report = json.loads((tmp_path / "bids" / "study" /
                         "ingestion_report.json").read_text())
    assert report["counts"] == {"ok": 2, "corrupted": 1, "filtered": 2,
                                "failed_qa": 1}
    # sidecars exist next to volumes (dcm2niix behaviour)
    vol = Path(by["a.npz"].dest)
    assert vol.with_suffix(".json").exists()


def test_ingested_dataset_flows_into_workflow(raw_dir, tmp_path):
    """The §2.1 output is directly queryable by the §2.3 engine."""
    manifest, _ = ingest_directory(raw_dir, tmp_path / "bids", "study")
    pipe = builtin_pipelines()["bias_correct"]
    work, excluded = query_available_work(manifest, pipe)
    assert len(work) == 2


# ---------------------------------------------------------------------------
# fused QA+checksum at ingest scale: mixed shape-buckets vs the numpy oracle
# ---------------------------------------------------------------------------

def test_qa_checksum_batched_mixed_shape_buckets_bit_exact():
    """Ingest-scale batching: volumes arrive in mixed shapes; each shape
    bucket goes through ONE ``qa_checksum_batched`` call. Every bucket must
    agree bit-exactly with the numpy oracle, and each row must equal the
    unbatched kernel on that volume (so bucketing never changes results)."""
    import jax.numpy as jnp
    from repro.kernels.checksum import (qa_checksum, qa_checksum_batched,
                                        qa_checksum_batched_ref)

    rng = np.random.default_rng(7)
    volumes = (
        [rng.normal(100, 20, (16, 16, 16)).astype(np.float32) for _ in range(3)]
        + [rng.normal(50, 9, (12, 12, 8)).astype(np.float32) for _ in range(4)]
        + [rng.normal(0, 1, (7, 5)).astype(np.float32) for _ in range(2)]
    )
    # NaN/Inf volumes exercise finite_count and the finite-only min/max/sum
    volumes[1] = volumes[1].copy()
    volumes[1][0, 0, 0] = np.nan
    volumes[4] = volumes[4].copy()
    volumes[4][3, 2, 1] = np.inf
    volumes[4][0, 1, 0] = -np.inf

    buckets = {}
    for v in volumes:
        buckets.setdefault(v.shape, []).append(v)
    assert len(buckets) == 3                         # genuinely mixed shapes

    for shape, vols in buckets.items():
        batch = np.stack(vols)
        got = qa_checksum_batched(jnp.asarray(batch), interpret=True)
        ref = qa_checksum_batched_ref(batch)
        for a, b in zip(got, ref):
            a = np.asarray(a)
            assert a.dtype == b.dtype
            assert np.array_equal(a, b, equal_nan=True), (shape, a, b)
        # row-wise: bucketed result == unbatched kernel per volume
        for i, v in enumerate(vols):
            s, q, c = qa_checksum(jnp.asarray(v), interpret=True)
            assert np.array_equal(np.asarray(s), np.asarray(got[0][i]))
            assert np.array_equal(np.asarray(q), np.asarray(got[1][i]),
                                  equal_nan=True)
            assert np.array_equal(np.asarray(c), np.asarray(got[2][i]))


def test_qa_checksum_batched_counts_nonfinite_voxels():
    """finite_count drives the ingest QA gate: it must count exactly the
    finite voxels of each volume in the bucket."""
    import jax.numpy as jnp
    from repro.kernels.checksum import qa_checksum_batched

    rng = np.random.default_rng(3)
    batch = rng.normal(0, 1, (4, 10, 10)).astype(np.float32)
    batch[1, 0, 0] = np.nan
    batch[2, 3, 3] = np.inf
    batch[2, 4, 4] = -np.inf
    batch[3] = np.nan                                # fully non-finite volume
    _, qa, cnt = qa_checksum_batched(jnp.asarray(batch), interpret=True)
    cnt = np.asarray(cnt)[:, 0]
    assert cnt.tolist() == [100, 99, 98, 0]
    qa = np.asarray(qa)
    assert qa[3, 0] == np.inf and qa[3, 1] == -np.inf   # empty-finite min/max
    assert qa[3, 2] == 0.0


def test_ingest_device_qa_uses_checksum_consistently(tmp_path):
    """device_qa ingest records carry the fused checksum; re-ingesting the
    same bytes reproduces it (content-derived, not run-derived)."""
    rng = np.random.default_rng(0)
    d = tmp_path / "raw"
    vol = rng.normal(100, 20, (16, 16, 16)).astype(np.float32)
    write_raw_dump(d / "a.npz", vol, subject="001", session="01",
                   protocol="T1w")
    _, rec1 = ingest_directory(d, tmp_path / "b1", "s", device_qa=True)
    _, rec2 = ingest_directory(d, tmp_path / "b2", "s", device_qa=True)
    assert rec1[0].checksum and rec1[0].checksum == rec2[0].checksum


# ---------------------------------------------------------------------------
# ingest-path correctness: host/device verdict parity, streamed ingest,
# atomic report commits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float16, np.float32, np.uint8,
                                   np.int16, np.uint16])
def test_host_and_device_qa_verdicts_agree_across_dtypes(tmp_path, dtype):
    """The host fast-QA reduces in float32 — the fused kernel's dtype — so
    both paths must reach the same accept/reject verdict for every input
    dtype. (Regression: native-dtype std/mean overflowed to inf on float16
    volumes at modest intensities, rejecting on the host path only.)"""
    rng = np.random.default_rng(5)
    d = tmp_path / "raw"
    # intensities chosen so a float16 sum overflows but a float32 one is fine
    if np.issubdtype(np.dtype(dtype), np.floating):
        vol = rng.normal(300, 40, (16, 16, 16)).astype(dtype)
    else:
        vol = rng.integers(50, 200, (16, 16, 16)).astype(dtype)
    write_raw_dump(d / "a.npz", vol, subject="001", session="01",
                   protocol="T1w")
    bad = vol.astype(np.float32)
    bad[0, 0, 0] = np.nan
    write_raw_dump(d / "b.npz", bad, subject="002", session="01",
                   protocol="T1w")
    _, rec_host = ingest_directory(d, tmp_path / "h", "s", device_qa=False)
    _, rec_dev = ingest_directory(d, tmp_path / "d", "s", device_qa=True)
    host = {r.source: r.status for r in rec_host}
    dev = {r.source: r.status for r in rec_dev}
    assert host == dev
    assert host["a.npz"] == "ok" and host["b.npz"] == "failed_qa"


def test_fast_qa_float16_not_rejected_by_overflow():
    """Direct regression for the native-dtype reduction: a bright float16
    volume whose f16 std/mean overflow must still pass host QA."""
    from repro.core.ingest import IngestRule, _fast_qa
    rng = np.random.default_rng(2)
    vol = rng.normal(400, 60, (24, 24, 24)).astype(np.float16)
    with np.errstate(over="ignore"):
        assert not np.isfinite(vol.astype(np.float16).std())   # the trap
    assert _fast_qa(vol, IngestRule()) == ""


def test_streamed_ingest_matches_fused_and_records_sha256(tmp_path,
                                                          monkeypatch):
    """Streamed device QA (chunked fold + in-flight sha256) must be
    bit-identical to the one-shot fused kernel, and the recorded sha256
    must be the digest of the committed .npy bytes."""
    import hashlib
    from repro.core import stream as stream_mod
    rng = np.random.default_rng(0)
    d = tmp_path / "raw"
    vol = rng.normal(100, 20, (48, 48, 48)).astype(np.float32)
    write_raw_dump(d / "a.npz", vol, subject="001", session="01",
                   protocol="T1w")
    # 64 KiB chunks over a ~432 KiB volume: several chunks, non-dividing tail
    monkeypatch.setenv(stream_mod.CHUNK_MB_ENV, "0.0625")
    _, rec_stream = ingest_directory(d, tmp_path / "s", "ds", device_qa=True)
    monkeypatch.setenv(stream_mod.STREAM_ENV, "0")
    _, rec_fused = ingest_directory(d, tmp_path / "f", "ds", device_qa=True)
    assert rec_stream[0].checksum == rec_fused[0].checksum
    dest = Path(rec_stream[0].dest)
    assert rec_stream[0].sha256 == hashlib.sha256(
        dest.read_bytes()).hexdigest()
    # the streamed and load-then-verify paths commit identical bytes
    assert dest.read_bytes() == Path(rec_fused[0].dest).read_bytes()
    report = json.loads((tmp_path / "s" / "ds" /
                         "ingestion_report.json").read_text())
    assert report["stream"]["chunks"] > 1
    assert report["stream"]["device_qa"] is True


def test_ingest_rule_default_not_shared_between_calls(raw_dir, tmp_path):
    """Regression: the rule default used to be one shared dataclass
    instance, so a caller mutating it changed every later call's filter."""
    import repro.core.ingest as ingest
    import inspect
    default = inspect.signature(ingest.ingest_directory) \
        .parameters["rule"].default
    assert default is None                     # construct-per-call
    _, rec1 = ingest_directory(raw_dir, tmp_path / "b1", "s")
    # simulate the old failure: mutate a rule the caller owns, re-ingest
    mine = IngestRule(allowed_protocols=("bold",))
    _, rec_bold = ingest_directory(raw_dir, tmp_path / "b2", "s", rule=mine)
    _, rec2 = ingest_directory(raw_dir, tmp_path / "b3", "s")
    assert [r.status for r in rec1] == [r.status for r in rec2]


def test_ingestion_report_commit_is_atomic(raw_dir, tmp_path, monkeypatch):
    """A crash mid-report-write must leave the previous report intact, not
    a torn file (tmp+fsync+rename discipline)."""
    from repro.core import ingest as ingest_mod
    manifest, _ = ingest_directory(raw_dir, tmp_path / "bids", "study")
    rp = tmp_path / "bids" / "study" / "ingestion_report.json"
    before = rp.read_bytes()
    json.loads(before)                              # valid committed report

    def torn_write(path, data, *, fsync=True):
        path = Path(path)
        if path.name == "ingestion_report.json":
            # crash after the tmp file is partially written, before rename
            tmp = path.with_name(".torn-tmp")
            tmp.write_bytes(data[: len(data) // 2])
            raise OSError("simulated crash mid-write")
        return real_write(path, data, fsync=fsync)

    real_write = ingest_mod.atomic_write_bytes
    monkeypatch.setattr(ingest_mod, "atomic_write_bytes", torn_write)
    with pytest.raises(OSError, match="simulated crash"):
        ingest_directory(raw_dir, tmp_path / "bids", "study")
    assert rp.read_bytes() == before                # old report untouched
    json.loads(rp.read_text())


def test_ingest_leaves_no_tmp_litter(raw_dir, tmp_path):
    ingest_directory(raw_dir, tmp_path / "bids", "study", device_qa=True)
    litter = [p for p in (tmp_path / "bids").rglob("*")
              if p.name.startswith(".") and "tmp" in p.name]
    assert litter == []
