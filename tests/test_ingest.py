"""Paper §2.1 ingestion: convert + filter + QA + BIDS organize."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.ingest import (IngestRule, ingest_directory, write_raw_dump)
from repro.core import builtin_pipelines, query_available_work


@pytest.fixture()
def raw_dir(tmp_path):
    rng = np.random.default_rng(0)
    d = tmp_path / "raw"
    good = rng.normal(100, 20, (16, 16, 16)).astype(np.float32)
    write_raw_dump(d / "a.npz", good, subject="001", session="01", protocol="T1w")
    write_raw_dump(d / "b.npz", good + 1, subject="001", session="02",
                   protocol="T1w")
    # filtered: wrong protocol
    write_raw_dump(d / "c.npz", good, subject="002", session="01", protocol="bold")
    # filtered: resolution out of bounds
    write_raw_dump(d / "d.npz", good, subject="002", session="02",
                   protocol="T1w", resolution_mm=5.0)
    # fails QA: NaNs
    bad = good.copy(); bad[0, 0, 0] = np.nan
    write_raw_dump(d / "e.npz", bad, subject="003", session="01", protocol="T1w")
    # corrupted file
    (d / "f.npz").write_bytes(b"not a dump")
    return d


def test_ingest_counts_and_bids(raw_dir, tmp_path):
    manifest, records = ingest_directory(raw_dir, tmp_path / "bids", "study")
    by = {r.source: r for r in records}
    assert by["a.npz"].status == "ok" and by["b.npz"].status == "ok"
    assert by["c.npz"].status == "filtered"
    assert by["d.npz"].status == "filtered"
    assert by["e.npz"].status == "failed_qa"
    assert by["f.npz"].status == "corrupted"
    # BIDS-valid and manifest sees exactly the 2 accepted scans
    assert manifest.validate() == []
    assert len(manifest.images) == 2
    report = json.loads((tmp_path / "bids" / "study" /
                         "ingestion_report.json").read_text())
    assert report["counts"] == {"ok": 2, "corrupted": 1, "filtered": 2,
                                "failed_qa": 1}
    # sidecars exist next to volumes (dcm2niix behaviour)
    vol = Path(by["a.npz"].dest)
    assert vol.with_suffix(".json").exists()


def test_ingested_dataset_flows_into_workflow(raw_dir, tmp_path):
    """The §2.1 output is directly queryable by the §2.3 engine."""
    manifest, _ = ingest_directory(raw_dir, tmp_path / "bids", "study")
    pipe = builtin_pipelines()["bias_correct"]
    work, excluded = query_available_work(manifest, pipe)
    assert len(work) == 2


# ---------------------------------------------------------------------------
# fused QA+checksum at ingest scale: mixed shape-buckets vs the numpy oracle
# ---------------------------------------------------------------------------

def test_qa_checksum_batched_mixed_shape_buckets_bit_exact():
    """Ingest-scale batching: volumes arrive in mixed shapes; each shape
    bucket goes through ONE ``qa_checksum_batched`` call. Every bucket must
    agree bit-exactly with the numpy oracle, and each row must equal the
    unbatched kernel on that volume (so bucketing never changes results)."""
    import jax.numpy as jnp
    from repro.kernels.checksum import (qa_checksum, qa_checksum_batched,
                                        qa_checksum_batched_ref)

    rng = np.random.default_rng(7)
    volumes = (
        [rng.normal(100, 20, (16, 16, 16)).astype(np.float32) for _ in range(3)]
        + [rng.normal(50, 9, (12, 12, 8)).astype(np.float32) for _ in range(4)]
        + [rng.normal(0, 1, (7, 5)).astype(np.float32) for _ in range(2)]
    )
    # NaN/Inf volumes exercise finite_count and the finite-only min/max/sum
    volumes[1] = volumes[1].copy()
    volumes[1][0, 0, 0] = np.nan
    volumes[4] = volumes[4].copy()
    volumes[4][3, 2, 1] = np.inf
    volumes[4][0, 1, 0] = -np.inf

    buckets = {}
    for v in volumes:
        buckets.setdefault(v.shape, []).append(v)
    assert len(buckets) == 3                         # genuinely mixed shapes

    for shape, vols in buckets.items():
        batch = np.stack(vols)
        got = qa_checksum_batched(jnp.asarray(batch), interpret=True)
        ref = qa_checksum_batched_ref(batch)
        for a, b in zip(got, ref):
            a = np.asarray(a)
            assert a.dtype == b.dtype
            assert np.array_equal(a, b, equal_nan=True), (shape, a, b)
        # row-wise: bucketed result == unbatched kernel per volume
        for i, v in enumerate(vols):
            s, q, c = qa_checksum(jnp.asarray(v), interpret=True)
            assert np.array_equal(np.asarray(s), np.asarray(got[0][i]))
            assert np.array_equal(np.asarray(q), np.asarray(got[1][i]),
                                  equal_nan=True)
            assert np.array_equal(np.asarray(c), np.asarray(got[2][i]))


def test_qa_checksum_batched_counts_nonfinite_voxels():
    """finite_count drives the ingest QA gate: it must count exactly the
    finite voxels of each volume in the bucket."""
    import jax.numpy as jnp
    from repro.kernels.checksum import qa_checksum_batched

    rng = np.random.default_rng(3)
    batch = rng.normal(0, 1, (4, 10, 10)).astype(np.float32)
    batch[1, 0, 0] = np.nan
    batch[2, 3, 3] = np.inf
    batch[2, 4, 4] = -np.inf
    batch[3] = np.nan                                # fully non-finite volume
    _, qa, cnt = qa_checksum_batched(jnp.asarray(batch), interpret=True)
    cnt = np.asarray(cnt)[:, 0]
    assert cnt.tolist() == [100, 99, 98, 0]
    qa = np.asarray(qa)
    assert qa[3, 0] == np.inf and qa[3, 1] == -np.inf   # empty-finite min/max
    assert qa[3, 2] == 0.0


def test_ingest_device_qa_uses_checksum_consistently(tmp_path):
    """device_qa ingest records carry the fused checksum; re-ingesting the
    same bytes reproduces it (content-derived, not run-derived)."""
    rng = np.random.default_rng(0)
    d = tmp_path / "raw"
    vol = rng.normal(100, 20, (16, 16, 16)).astype(np.float32)
    write_raw_dump(d / "a.npz", vol, subject="001", session="01",
                   protocol="T1w")
    _, rec1 = ingest_directory(d, tmp_path / "b1", "s", device_qa=True)
    _, rec2 = ingest_directory(d, tmp_path / "b2", "s", device_qa=True)
    assert rec1[0].checksum and rec1[0].checksum == rec2[0].checksum
