"""Quickstart: the paper's workflow in ~40 lines.

Synthesizes a small BIDS dataset, queries the work available for a pipeline,
generates the SLURM array + runs locally, and shows the provenance trail.

    PYTHONPATH=src python examples/quickstart.py
"""
import json
import tempfile
from pathlib import Path

from repro.core import (LocalRunner, builtin_pipelines, generate_jobs,
                        query_available_work, synthesize_dataset)

with tempfile.TemporaryDirectory() as td:
    # 1. a BIDS-organized dataset lands on the archive
    ds = synthesize_dataset(Path(td), "demo", n_subjects=2,
                            sessions_per_subject=1, shape=(12, 12, 12))
    print(f"dataset {ds.name}: {len(ds.images)} images, "
          f"{len(ds.sessions())} sessions, BIDS problems: {ds.validate()}")

    # 2. query what needs processing + generate the job array
    pipe = builtin_pipelines()["bias_correct"]
    plan = generate_jobs(ds, pipe, Path(td) / "jobs")
    print(f"pipeline {pipe.name} (digest {pipe.digest()}): "
          f"{len(plan.units)} work units")
    print(f"SLURM array script: {plan.slurm_script}")

    # 3. burst-to-local execution (same units the cluster would run)
    results = LocalRunner(pipe, ds.root).run(plan.units)
    print("results:", [(r.unit.job_id, r.status, f"{r.seconds:.2f}s")
                       for r in results])

    # 4. provenance: who / when / inputs / digest — next to every output
    prov = json.loads((Path(plan.units[0].out_dir) / "provenance.json").read_text())
    print("provenance keys:", sorted(prov))

    # 5. idempotency: the query now finds nothing to do
    work, excluded = query_available_work(ds, pipe)
    print(f"re-query: {len(work)} units to run; "
          f"exclusions: {[e.reason for e in excluded]}")
