"""Networked cluster processing (ROADMAP: cluster transport): the coordinator
serves its WorkQueue over a TCP JSON-lines socket, its own nodes talk to it
through the same client a remote machine would use, and a genuinely separate
worker *process* dials in, registers, steals work, and commits to shared
storage — with every host serving repeated inputs from its content-addressed
cache instead of shared storage (watch ``cache_hit`` flip to True on re-runs).

    PYTHONPATH=src python examples/process_dataset_rpc.py
"""
import os
import shutil
import subprocess
import sys
import tempfile
import threading
import time
from collections import Counter
from pathlib import Path

from repro.core import (Provenance, builtin_pipelines, query_available_work,
                        synthesize_dataset)
from repro.dist import ClusterRunner

with tempfile.TemporaryDirectory() as td:
    td = Path(td)
    ds = synthesize_dataset(td / "ds", "MASIVar-rpc", n_subjects=10,
                            sessions_per_subject=2, shape=(16, 16, 16))
    pipe = builtin_pipelines()["bias_correct"]
    units, _ = query_available_work(ds, pipe)
    print(f"work query: {len(units)} units")

    def run_once(tag):
        runner = ClusterRunner(pipe, ds.root, nodes=2, transport="rpc",
                               poll_s=0.03, cache_dir=td / "host-cache")
        got = {}
        t = threading.Thread(target=lambda: got.update(
            r=runner.run(query_available_work(ds, pipe)[0])))
        t.start()
        while runner.server is None and t.is_alive():
            time.sleep(0.01)

        # one worker host in its own process: joins via the CLI entrypoint,
        # with its own input cache (REPRO_CACHE_DIR) like a real machine
        env = dict(os.environ,
                   PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
                   REPRO_CACHE_DIR=str(td / "ext-cache"))
        worker = subprocess.Popen(
            [sys.executable, "-m", "repro.dist.rpc", "work",
             "--addr", runner.server.addr_str, "--pipeline", pipe.name,
             "--data-root", str(ds.root), "--node-id", "ext-host"],
            env=env, text=True, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT)
        t.join()
        print(f"[{tag}] worker process said: {worker.communicate()[0].strip()}")
        results = got["r"]
        counts = Counter(r.status for r in results)
        st = runner.stats
        hits = sum(1 for u in units
                   if Provenance.load(Path(u.out_dir)).cache_hit)
        print(f"[{tag}] {counts['ok']}/{len(units)} ok "
              f"(+{counts.get('speculative', 0)} speculative) · "
              f"processed {st.processed} · remote nodes {st.remote_nodes}")
        print(f"[{tag}] coordinator-host cache: {st.cache} · "
              f"{hits} commits stamped cache_hit=True")
        assert counts["ok"] == len(units)

    run_once("cold")
    # wipe derivatives but keep the host caches: the re-run's inputs never
    # touch shared storage — this is the repeated-cohort path the per-host
    # cache exists for
    shutil.rmtree(Path(ds.root) / "derivatives")
    run_once("warm")
