"""Multi-node burst processing (ROADMAP: beyond one host): a 4-node
in-process cluster drains one work queue with work-stealing, survives an
injected node death via lease reaping, and speculates cross-node twins for
stragglers — all arbitrated down to exactly one ok provenance per image.

    PYTHONPATH=src python examples/process_dataset_cluster.py
"""
import tempfile
import threading
import time
from collections import Counter
from pathlib import Path

from repro.core import (builtin_pipelines, query_available_work,
                        synthesize_dataset)
from repro.dist import ClusterRunner

with tempfile.TemporaryDirectory() as td:
    ds = synthesize_dataset(Path(td), "MASIVar-cluster", n_subjects=12,
                            sessions_per_subject=2, shape=(16, 16, 16))
    pipe = builtin_pipelines()["bias_correct"]
    units, excluded = query_available_work(ds, pipe)
    print(f"work query: {len(units)} units, {len(excluded)} excluded")

    # one late-in-the-run unit straggles once (its speculative twin, the
    # second arrival, does not re-sleep and wins)
    slow = {"id": units[16].job_id, "n": 0}
    slow_lock = threading.Lock()

    def straggle(unit, attempt):
        if unit.job_id == slow["id"]:
            with slow_lock:
                first = slow["n"] == 0
                slow["n"] += 1
            if first:
                time.sleep(1.2)

    runner = ClusterRunner(pipe, ds.root, nodes=4,
                           die_after={"node-3": 2},      # node-3 crashes
                           lease_ttl_s=0.6, hb_interval_s=0.1,
                           straggler_factor=2.0, straggler_min_s=0.2,
                           fault_hook=straggle)
    t0 = time.time()
    results = runner.run(units)
    dt = time.time() - t0

    counts = Counter(r.status for r in results)
    st = runner.stats
    print(f"{counts['ok']}/{len(units)} ok in {dt:.2f}s "
          f"(+{counts.get('speculative', 0)} speculative duplicates)")
    print(f"per-node processed: {st.processed}")
    print(f"steals: {st.steals}  requeued after death: {st.requeued}  "
          f"dead: {st.dead_nodes}  twins launched: {st.speculated}")

    # a second submitter racing the (now finished) cluster sees zero work
    work2, excl2 = query_available_work(ds, pipe)
    print(f"re-query: {len(work2)} units remain; "
          f"{sum('digest match' in e.reason for e in excl2)} already processed")
    assert counts["ok"] == len(units)
