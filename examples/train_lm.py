"""End-to-end driver: train a ~100M-param llama3.2-family model for a few
hundred steps on the sharded data pipeline, with async checkpointing and a
mid-run simulated crash + resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import tempfile
from pathlib import Path

import numpy as np

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as td:
        data = str(Path(td) / "data")
        ckpt = str(Path(td) / "ckpt")
        half = args.steps // 2
        print(f"=== phase 1: {half} steps, then 'crash' ===")
        _, losses1 = train(args.arch, steps=half, batch=8, seq=128,
                           data_dir=data, ckpt_dir=ckpt, ckpt_every=25)
        print(f"=== phase 2: resume from checkpoint, to {args.steps} ===")
        _, losses2 = train(args.arch, steps=args.steps, batch=8, seq=128,
                           data_dir=data, ckpt_dir=ckpt, ckpt_every=50,
                           resume=True)
        print(f"loss: start {np.mean(losses1[:10]):.3f} -> "
              f"end {np.mean(losses2[-10:]):.3f}")
        assert np.mean(losses2[-10:]) < np.mean(losses1[:10]), \
            "training should reduce loss"
        print("OK")


if __name__ == "__main__":
    main()
