"""Full paper workflow (Fig. 3): tiered storage, multiple pipelines run by
the parallel pipelined executor (workers=2, input prefetch), fault injection
+ retry, straggler speculation, cold archival, cost accounting.

    PYTHONPATH=src python examples/process_dataset.py
"""
import tempfile
from pathlib import Path

from repro.core import (LocalRunner, TieredStore, builtin_pipelines,
                        generate_jobs, paper_table1, resource_status,
                        synthesize_dataset)

with tempfile.TemporaryDirectory() as td:
    td = Path(td)
    ds = synthesize_dataset(td / "archive", "MASIVar-mini", n_subjects=3,
                            sessions_per_subject=2, shape=(16, 16, 16))
    store = TieredStore(td / "tiers")
    print("resource status:", resource_status(td))

    flaky = {"left": 2}

    def chaos(unit, attempt):      # two injected node failures
        if flaky["left"] > 0 and attempt == 1:
            flaky["left"] -= 1
            raise RuntimeError("injected node failure")

    for name in ("bias_correct", "affine_register", "segment_unest"):
        pipe = builtin_pipelines()[name]
        plan = generate_jobs(ds, pipe, td / "jobs" / name)
        runner = LocalRunner(pipe, ds.root, max_retries=2, fault_hook=chaos,
                             workers=2)        # parallel pipelined executor
        results = runner.run(plan.units)
        ok = sum(r.status == "ok" for r in results)
        retried = sum(r.attempts > 1 for r in results if r.status == "ok")
        print(f"{name:16s}: {ok}/{len(plan.units)} ok "
              f"({retried} recovered by retry), "
              f"excluded CSV: {plan.exclusion_csv}")

    # nightly archival to the Glacier-style cold tier
    derivs = list((Path(ds.root) / "derivatives").rglob("*.npy"))[:4]
    for d in derivs:
        store.put(d, f"backup/{d.name}", tier="hot")
        store.archive_to_cold(f"backup/{d.name}")
    print(f"archived {len(derivs)} derivatives to cold tier; "
          f"yearly storage cost: {store.storage_cost_per_year()}")

    print("\npaper Table 1 reproduction:")
    for env, row in paper_table1().items():
        print(f"  {env:6s}: ${row['total_cost']:>5.2f} total, "
              f"{row['throughput_gbps']} Gb/s, {row['latency_ms']} ms")
