"""Serve a small model with batched requests: prefill + greedy decode against
KV/SSM caches, across three architecture families.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.configs import get_config
from repro.launch.serve import serve_batch

rng = np.random.default_rng(0)
for arch in ("llama3.2-1b", "rwkv6-1.6b", "zamba2-1.2b"):
    cfg = get_config(arch).reduced()
    prompts = rng.integers(0, cfg.vocab_size, (2, 24), dtype=np.int32)
    toks = serve_batch(arch, prompts, max_new=8)
    print(f"{arch:14s} generated: {toks.tolist()}")
